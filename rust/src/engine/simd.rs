//! Explicit SIMD micro-kernels with one-time runtime dispatch (DESIGN.md §14).
//!
//! PR 4's tiled kernels arranged the dense pull path so LLVM *could*
//! auto-vectorize it; this module makes the vector shape explicit —
//! AVX2 on x86_64, NEON on aarch64 — and keeps the scalar `lane_tile`
//! as the authoritative bitwise reference. The dispatch decision is made
//! once per process (a [`std::sync::OnceLock`], seeded from CPU feature
//! detection or the `CORRSH_KERNEL` env override) and every hot loop
//! branches on the cached [`Variant`].
//!
//! ## The bitwise contract
//!
//! Every vector kernel reproduces the scalar reference chain *exactly*:
//!
//! * **Dense tiles.** The packed ref layout (`packed[k·8 + lane]`, see
//!   `kernel::pack_block`) already holds one 8-wide f32 vector per feature
//!   index, so an AVX2 ymm (or a NEON float32x4 pair) *is* the scalar
//!   `acc[i][lane]` array — per-(arm, lane) f32 chains, folded into f64
//!   every [`SEG_LEN`] features via `cvtps→pd` (an exact conversion) in
//!   the same segment order. There is no k-tail in the vector dimension:
//!   tiles are zero-padded to [`REF_LANES`] lanes by construction.
//! * **No FMA.** The scalar reference rounds the multiply and the add
//!   separately (`*lane += a * y` is two rounded f32 ops). A fused
//!   multiply-add skips the intermediate rounding and would diverge by
//!   an ulp on the pull path — so the kernels deliberately use separate
//!   `mul` + `add` intrinsics. The win here is width and port pressure,
//!   not fusion.
//! * **Sparse corrections.** The densified-reference walk in
//!   `native::sparse_block` is vectorized over *runs* of consecutive
//!   column indices (no gathers — where the index run aligns, the values
//!   and the scratch row are both contiguous). Runs of at least
//!   [`RUN_MIN`] elements go through a 4-lane f64 kernel whose scalar
//!   mirror ([`sparse_run_scalar`]) uses the identical lane/fold order,
//!   so scalar, AVX2 and NEON walks agree bitwise *with each other* (the
//!   lane split is a deliberate, tested reassociation of the old
//!   sequential f64 sum; engine-level sparse tests compare against exact
//!   oracles with tolerances, DESIGN.md §14).
//!
//! ## Unsafe policy
//!
//! All `unsafe` on the compute path lives in this module (CI gates this):
//! `#[target_feature]` kernels plus the guarded dispatch calls into them.
//! Every call site re-checks the CPU feature (std caches the cpuid probe
//! in an atomic, so the guard costs one relaxed load) — a [`Variant`]
//! value alone is never trusted as proof the instruction set exists, so
//! forcing e.g. `Avx2` through a test hook on unsupported hardware safely
//! degrades to the scalar kernel instead of executing illegal
//! instructions. No raw pointer escapes the module; every offset is
//! bounded by slice-length assertions on kernel entry.

use std::sync::OnceLock;

/// Reference rows per packed tile — one 8-wide f32 vector per feature.
pub const REF_LANES: usize = 8;
/// Features per f32 accumulation segment before folding into f64. Bounds
/// the f32 chain error at ~`SEG_LEN · ε` worst-case regardless of `dim`.
pub const SEG_LEN: usize = 64;
/// Minimum consecutive-index run length worth entering the 4-lane sparse
/// kernel; shorter runs stay on the element loop (same elem order).
pub const RUN_MIN: usize = 8;

/// A dispatched kernel implementation. `Scalar` is the authoritative
/// reference; the vector variants are bitwise-equal accelerations of it
/// (property-gated in `tests/dense_tiles.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Portable reference kernels (always available, always correct).
    Scalar,
    /// x86_64 AVX2 (256-bit f32 / f64 vectors). Never uses FMA — see the
    /// module docs for why fusion would break the bitwise contract.
    Avx2,
    /// aarch64 NEON (128-bit vector pairs mirroring the AVX2 structure).
    Neon,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Avx2 => "avx2",
            Variant::Neon => "neon",
        }
    }

    /// Stable numeric code for bench/metrics rows (0 scalar, 1 avx2, 2 neon).
    pub fn code(self) -> u8 {
        match self {
            Variant::Scalar => 0,
            Variant::Avx2 => 1,
            Variant::Neon => 2,
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Probe the CPU once and pick the widest variant it supports.
pub fn detect() -> Variant {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Variant::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Variant::Neon;
        }
    }
    Variant::Scalar
}

/// Resolve a requested kernel name (`CORRSH_KERNEL`) against this host.
/// `None`/`"auto"` → [`detect`]; forcing a variant the host cannot run is
/// a hard error, not a silent fallback — a forced run that quietly
/// downgraded would invalidate whatever the force was for.
pub fn resolve(requested: Option<&str>) -> Result<Variant, String> {
    match requested {
        None | Some("auto") => Ok(detect()),
        Some("scalar") => Ok(Variant::Scalar),
        Some("avx2") => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    return Ok(Variant::Avx2);
                }
            }
            Err("CORRSH_KERNEL=avx2: AVX2 is not available on this host".to_string())
        }
        Some("neon") => {
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    return Ok(Variant::Neon);
                }
            }
            Err("CORRSH_KERNEL=neon: NEON is not available on this host".to_string())
        }
        Some(other) => Err(format!(
            "invalid CORRSH_KERNEL value {other:?} (expected scalar|avx2|neon|auto)"
        )),
    }
}

static ACTIVE: OnceLock<Variant> = OnceLock::new();

/// The process-wide dispatched variant, resolved once on first use from
/// `CORRSH_KERNEL` (default `auto`). An invalid override is a hard error;
/// CLIs and the server validate eagerly via [`startup_check`] so the
/// failure is a clean exit rather than a mid-pull panic.
pub fn active() -> Variant {
    *ACTIVE.get_or_init(|| match resolve(env_override().as_deref()) {
        Ok(v) => v,
        Err(e) => panic!("{e}"),
    })
}

/// Eager validation of the `CORRSH_KERNEL` override for process startup.
pub fn startup_check() -> crate::util::error::Result<Variant> {
    resolve(env_override().as_deref()).map_err(crate::util::error::Error::msg)
}

fn env_override() -> Option<String> {
    std::env::var("CORRSH_KERNEL").ok()
}

/// One-line dispatch report for `corrsh kernelinfo` and debugging.
pub fn kernel_info() -> String {
    let source = if env_override().is_some() { "env" } else { "auto" };
    format!(
        "kernel_variant={} source={} detected={} arch={} ref_lanes={} seg_len={} run_min={}",
        active(),
        source,
        detect(),
        std::env::consts::ARCH,
        REF_LANES,
        SEG_LEN,
        RUN_MIN
    )
}

// ---------------------------------------------------------------------------
// Dense tile kernels
// ---------------------------------------------------------------------------

/// The scalar reference micro-kernel: per-(arm, lane) f32 chains of
/// `op(a, y)` over one packed 8-lane ref tile, folded to f64 every
/// [`SEG_LEN`] features. Each (i, l) chain is independent, so values don't
/// depend on MR or tile membership. Full segments come out of
/// `chunks_exact` and the tail out of its explicit `remainder()`, so the
/// fold boundary is structural rather than an arithmetic bound — the SIMD
/// kernels reproduce exactly this segmentation.
pub fn lane_tile_scalar<const MR: usize>(
    rows: &[&[f32]; MR],
    packed: &[f32],
    op: impl Fn(f32, f32) -> f32 + Copy,
) -> [[f64; REF_LANES]; MR] {
    let dim = rows[0].len();
    debug_assert_eq!(packed.len(), dim * REF_LANES);
    let mut wide = [[0f64; REF_LANES]; MR];
    let mut segs = packed.chunks_exact(SEG_LEN * REF_LANES);
    let mut k0 = 0usize;
    for seg in segs.by_ref() {
        fold_segment(rows, k0, seg, op, &mut wide);
        k0 += SEG_LEN;
    }
    let tail = segs.remainder();
    if !tail.is_empty() {
        fold_segment(rows, k0, tail, op, &mut wide);
    }
    wide
}

/// One f32 accumulation segment (≤ [`SEG_LEN`] features starting at `k0`)
/// folded into the f64 accumulators, in lane order.
#[inline]
fn fold_segment<const MR: usize>(
    rows: &[&[f32]; MR],
    k0: usize,
    seg: &[f32],
    op: impl Fn(f32, f32) -> f32 + Copy,
    wide: &mut [[f64; REF_LANES]; MR],
) {
    let mut acc = [[0f32; REF_LANES]; MR];
    for (k, y) in seg.chunks_exact(REF_LANES).enumerate() {
        for i in 0..MR {
            let a = rows[i][k0 + k];
            for (lane, &yv) in acc[i].iter_mut().zip(y) {
                *lane += op(a, yv);
            }
        }
    }
    for i in 0..MR {
        for (w, &narrow) in wide[i].iter_mut().zip(&acc[i]) {
            *w += narrow as f64;
        }
    }
}

/// Σ_k a_i[k] · y_l[k] (the L2/cosine norm-trick operand), dispatched.
pub fn dot_tile<const MR: usize>(
    v: Variant,
    rows: &[&[f32]; MR],
    packed: &[f32],
) -> [[f64; REF_LANES]; MR] {
    match v {
        #[cfg(target_arch = "x86_64")]
        Variant::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: the match guard just verified AVX2 on this CPU, and
            // the kernel asserts all slice bounds on entry.
            unsafe { x86::lane_tile::<MR, true>(rows, packed) }
        }
        #[cfg(target_arch = "aarch64")]
        Variant::Neon if std::arch::is_aarch64_feature_detected!("neon") => {
            // SAFETY: the match guard just verified NEON on this CPU, and
            // the kernel asserts all slice bounds on entry.
            unsafe { neon::lane_tile::<MR, true>(rows, packed) }
        }
        _ => lane_tile_scalar(rows, packed, |a, y| a * y),
    }
}

/// Σ_k |a_i[k] − y_l[k]|, dispatched.
pub fn l1_tile<const MR: usize>(
    v: Variant,
    rows: &[&[f32]; MR],
    packed: &[f32],
) -> [[f64; REF_LANES]; MR] {
    match v {
        #[cfg(target_arch = "x86_64")]
        Variant::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: the match guard just verified AVX2 on this CPU, and
            // the kernel asserts all slice bounds on entry.
            unsafe { x86::lane_tile::<MR, false>(rows, packed) }
        }
        #[cfg(target_arch = "aarch64")]
        Variant::Neon if std::arch::is_aarch64_feature_detected!("neon") => {
            // SAFETY: the match guard just verified NEON on this CPU, and
            // the kernel asserts all slice bounds on entry.
            unsafe { neon::lane_tile::<MR, false>(rows, packed) }
        }
        _ => lane_tile_scalar(rows, packed, |a, y| (a - y).abs()),
    }
}

// ---------------------------------------------------------------------------
// Sparse correction walks (densified-reference fast path)
// ---------------------------------------------------------------------------

pub(crate) const OP_L1: u8 = 0;
pub(crate) const OP_L2: u8 = 1;
pub(crate) const OP_DOT: u8 = 2;

/// One element of a sparse correction term, in f64 (matches the scalar
/// loops these walks replaced in `native::sparse_block`).
#[inline]
fn elem<const OP: u8>(a: f32, y: f32) -> f64 {
    if OP == OP_L1 {
        ((a - y).abs() - y.abs()) as f64
    } else if OP == OP_L2 {
        let d = (a - y) as f64;
        d * d - y as f64 * y as f64
    } else {
        a as f64 * y as f64
    }
}

/// The scalar mirror of the vector run kernels: 4 independent f64 lanes
/// over `chunks_exact(4)`, folded `(l0 + l1) + (l2 + l3)`, scalar tail
/// appended — the same shape `distance::dense` uses. AVX2/NEON reproduce
/// this chain exactly, so every variant agrees bitwise.
fn sparse_run_scalar<const OP: u8>(av: &[f32], yv: &[f32]) -> f64 {
    debug_assert_eq!(av.len(), yv.len());
    let mut lane = [0f64; 4];
    for (a, y) in av.chunks_exact(4).zip(yv.chunks_exact(4)) {
        for l in 0..4 {
            lane[l] += elem::<OP>(a[l], y[l]);
        }
    }
    let mut s = (lane[0] + lane[1]) + (lane[2] + lane[3]);
    let tail = av.len() / 4 * 4;
    for (&a, &y) in av[tail..].iter().zip(&yv[tail..]) {
        s += elem::<OP>(a, y);
    }
    s
}

/// Run-segmented sparse correction walk: maximal runs of consecutive
/// column indices are contiguous in both `values` and the densified
/// `scratch` row, so runs of ≥ [`RUN_MIN`] elements take a gather-free
/// 4-lane kernel; short runs and stragglers stay on the element loop.
/// Run segmentation depends only on `indices`, never on the variant.
fn sparse_corr<const OP: u8>(v: Variant, indices: &[u32], values: &[f32], scratch: &[f32]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    let mut acc = 0f64;
    for (start, len) in crate::distance::sparse::index_runs(indices) {
        let c0 = indices[start] as usize;
        if len >= RUN_MIN {
            let av = &values[start..start + len];
            let yv = &scratch[c0..c0 + len];
            acc += match v {
                #[cfg(target_arch = "x86_64")]
                Variant::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
                    // SAFETY: the match guard just verified AVX2 on this
                    // CPU; the kernel asserts `av.len() == yv.len()`.
                    unsafe { x86::sparse_run::<OP>(av, yv) }
                }
                #[cfg(target_arch = "aarch64")]
                Variant::Neon if std::arch::is_aarch64_feature_detected!("neon") => {
                    // SAFETY: the match guard just verified NEON on this
                    // CPU; the kernel asserts `av.len() == yv.len()`.
                    unsafe { neon::sparse_run::<OP>(av, yv) }
                }
                _ => sparse_run_scalar::<OP>(av, yv),
            };
        } else {
            for t in 0..len {
                acc += elem::<OP>(values[start + t], scratch[c0 + t]);
            }
        }
    }
    acc
}

/// L1 correction of a densified reference: `Σ (|a−y| − |y|)` over the
/// arm's support (added to the ref's precomputed |·| row reduction).
pub fn sparse_l1_corr(v: Variant, indices: &[u32], values: &[f32], scratch: &[f32]) -> f64 {
    sparse_corr::<OP_L1>(v, indices, values, scratch)
}

/// L2 correction: `Σ ((a−y)² − y²)` in f64 over the arm's support.
pub fn sparse_l2_corr(v: Variant, indices: &[u32], values: &[f32], scratch: &[f32]) -> f64 {
    sparse_corr::<OP_L2>(v, indices, values, scratch)
}

/// Sparse dot product `Σ a·y` in f64 over the arm's support (cosine).
pub fn sparse_dot(v: Variant, indices: &[u32], values: &[f32], scratch: &[f32]) -> f64 {
    sparse_corr::<OP_DOT>(v, indices, values, scratch)
}

// ---------------------------------------------------------------------------
// AVX2 kernels (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 mirrors of the scalar reference kernels. Deliberately no FMA
    //! (see the module docs): `mul` + `add` keep the scalar rounding
    //! sequence, the 256-bit width and the halved loop overhead are the
    //! entire win. `_mm256_cvtps_pd` is an exact widening conversion, so
    //! the per-segment f64 folds match the scalar `as f64` casts bitwise.

    use super::{elem, REF_LANES, SEG_LEN};
    use core::arch::x86_64::*;

    // SAFETY: callers verify `avx2` via `is_x86_feature_detected!` before
    // every call; all pointer offsets below stay inside the slice lengths
    // asserted on entry (packed holds dim·8 floats, each row holds dim).
    #[target_feature(enable = "avx2")]
    pub unsafe fn lane_tile<const MR: usize, const DOT: bool>(
        rows: &[&[f32]; MR],
        packed: &[f32],
    ) -> [[f64; REF_LANES]; MR] {
        let dim = rows[0].len();
        assert_eq!(packed.len(), dim * REF_LANES);
        for r in rows.iter() {
            assert_eq!(r.len(), dim);
        }
        let sign = _mm256_set1_ps(-0.0);
        // f64 accumulators: low/high 4 lanes of each arm's 8-lane tile.
        let mut lo = [_mm256_setzero_pd(); MR];
        let mut hi = [_mm256_setzero_pd(); MR];
        let mut k0 = 0usize;
        while k0 < dim {
            let k1 = (k0 + SEG_LEN).min(dim);
            let mut acc = [_mm256_setzero_ps(); MR];
            for k in k0..k1 {
                let y = _mm256_loadu_ps(packed.as_ptr().add(k * REF_LANES));
                for i in 0..MR {
                    let a = _mm256_set1_ps(*rows[i].get_unchecked(k));
                    let t = if DOT {
                        _mm256_mul_ps(a, y)
                    } else {
                        _mm256_andnot_ps(sign, _mm256_sub_ps(a, y))
                    };
                    acc[i] = _mm256_add_ps(acc[i], t);
                }
            }
            for i in 0..MR {
                let narrow_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(acc[i]));
                let narrow_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(acc[i]));
                lo[i] = _mm256_add_pd(lo[i], narrow_lo);
                hi[i] = _mm256_add_pd(hi[i], narrow_hi);
            }
            k0 = k1;
        }
        let mut wide = [[0f64; REF_LANES]; MR];
        for i in 0..MR {
            _mm256_storeu_pd(wide[i].as_mut_ptr(), lo[i]);
            _mm256_storeu_pd(wide[i].as_mut_ptr().add(4), hi[i]);
        }
        wide
    }

    // SAFETY: callers verify `avx2` before every call; `av`/`yv` lengths
    // are asserted equal on entry and every offset stays below that
    // length (n4·4 ≤ len for the vector body, then the scalar tail).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sparse_run<const OP: u8>(av: &[f32], yv: &[f32]) -> f64 {
        use super::{OP_L1, OP_L2};
        assert_eq!(av.len(), yv.len());
        let n4 = av.len() / 4;
        let sign = _mm_set1_ps(-0.0);
        let mut lane = _mm256_setzero_pd();
        for c in 0..n4 {
            let a = _mm_loadu_ps(av.as_ptr().add(c * 4));
            let y = _mm_loadu_ps(yv.as_ptr().add(c * 4));
            let term = if OP == OP_L1 {
                let d = _mm_andnot_ps(sign, _mm_sub_ps(a, y));
                _mm256_cvtps_pd(_mm_sub_ps(d, _mm_andnot_ps(sign, y)))
            } else if OP == OP_L2 {
                let d = _mm256_cvtps_pd(_mm_sub_ps(a, y));
                let yd = _mm256_cvtps_pd(y);
                _mm256_sub_pd(_mm256_mul_pd(d, d), _mm256_mul_pd(yd, yd))
            } else {
                _mm256_mul_pd(_mm256_cvtps_pd(a), _mm256_cvtps_pd(y))
            };
            lane = _mm256_add_pd(lane, term);
        }
        let mut l = [0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), lane);
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        for t in n4 * 4..av.len() {
            s += elem::<OP>(*av.get_unchecked(t), *yv.get_unchecked(t));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// NEON kernels (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON mirrors of the AVX2 kernels: each 8-lane f32 tile is a
    //! float32x4 pair, each 4-lane f64 accumulator a float64x2 pair, with
    //! the identical mul/add (never fused) and cvt-fold sequence. Kept a
    //! strict structural mirror of `x86::*` — x86_64 CI never type-checks
    //! this module, so reviewability *is* the correctness story here
    //! (plus the differential property on aarch64 hosts).

    use super::{elem, REF_LANES, SEG_LEN};
    use core::arch::aarch64::*;

    // SAFETY: callers verify `neon` via `is_aarch64_feature_detected!`
    // before every call; all pointer offsets below stay inside the slice
    // lengths asserted on entry.
    #[target_feature(enable = "neon")]
    pub unsafe fn lane_tile<const MR: usize, const DOT: bool>(
        rows: &[&[f32]; MR],
        packed: &[f32],
    ) -> [[f64; REF_LANES]; MR] {
        let dim = rows[0].len();
        assert_eq!(packed.len(), dim * REF_LANES);
        for r in rows.iter() {
            assert_eq!(r.len(), dim);
        }
        // f64 accumulators: four 2-lane quarters of each 8-lane tile.
        let mut wide_v = [[vdupq_n_f64(0.0); 4]; MR];
        let mut k0 = 0usize;
        while k0 < dim {
            let k1 = (k0 + SEG_LEN).min(dim);
            // f32 accumulators: low/high 4 lanes of each 8-lane tile.
            let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
            for k in k0..k1 {
                let p = packed.as_ptr().add(k * REF_LANES);
                let y0 = vld1q_f32(p);
                let y1 = vld1q_f32(p.add(4));
                for i in 0..MR {
                    let a = vdupq_n_f32(*rows[i].get_unchecked(k));
                    let (t0, t1) = if DOT {
                        (vmulq_f32(a, y0), vmulq_f32(a, y1))
                    } else {
                        (vabsq_f32(vsubq_f32(a, y0)), vabsq_f32(vsubq_f32(a, y1)))
                    };
                    acc[i][0] = vaddq_f32(acc[i][0], t0);
                    acc[i][1] = vaddq_f32(acc[i][1], t1);
                }
            }
            for i in 0..MR {
                wide_v[i][0] = vaddq_f64(wide_v[i][0], vcvt_f64_f32(vget_low_f32(acc[i][0])));
                wide_v[i][1] = vaddq_f64(wide_v[i][1], vcvt_f64_f32(vget_high_f32(acc[i][0])));
                wide_v[i][2] = vaddq_f64(wide_v[i][2], vcvt_f64_f32(vget_low_f32(acc[i][1])));
                wide_v[i][3] = vaddq_f64(wide_v[i][3], vcvt_f64_f32(vget_high_f32(acc[i][1])));
            }
            k0 = k1;
        }
        let mut wide = [[0f64; REF_LANES]; MR];
        for i in 0..MR {
            for (q, quarter) in wide_v[i].iter().enumerate() {
                vst1q_f64(wide[i].as_mut_ptr().add(q * 2), *quarter);
            }
        }
        wide
    }

    // SAFETY: callers verify `neon` before every call; `av`/`yv` lengths
    // are asserted equal on entry and every offset stays below that
    // length (n4·4 ≤ len for the vector body, then the scalar tail).
    #[target_feature(enable = "neon")]
    pub unsafe fn sparse_run<const OP: u8>(av: &[f32], yv: &[f32]) -> f64 {
        use super::{OP_L1, OP_L2};
        assert_eq!(av.len(), yv.len());
        let n4 = av.len() / 4;
        // lanes 0–1 and 2–3 of the scalar mirror's 4-lane accumulator.
        let mut l01 = vdupq_n_f64(0.0);
        let mut l23 = vdupq_n_f64(0.0);
        for c in 0..n4 {
            let a = vld1q_f32(av.as_ptr().add(c * 4));
            let y = vld1q_f32(yv.as_ptr().add(c * 4));
            if OP == OP_L1 {
                let t = vsubq_f32(vabsq_f32(vsubq_f32(a, y)), vabsq_f32(y));
                l01 = vaddq_f64(l01, vcvt_f64_f32(vget_low_f32(t)));
                l23 = vaddq_f64(l23, vcvt_f64_f32(vget_high_f32(t)));
            } else if OP == OP_L2 {
                let d = vsubq_f32(a, y);
                let d_lo = vcvt_f64_f32(vget_low_f32(d));
                let d_hi = vcvt_f64_f32(vget_high_f32(d));
                let y_lo = vcvt_f64_f32(vget_low_f32(y));
                let y_hi = vcvt_f64_f32(vget_high_f32(y));
                l01 = vaddq_f64(l01, vsubq_f64(vmulq_f64(d_lo, d_lo), vmulq_f64(y_lo, y_lo)));
                l23 = vaddq_f64(l23, vsubq_f64(vmulq_f64(d_hi, d_hi), vmulq_f64(y_hi, y_hi)));
            } else {
                let a_lo = vcvt_f64_f32(vget_low_f32(a));
                let a_hi = vcvt_f64_f32(vget_high_f32(a));
                let y_lo = vcvt_f64_f32(vget_low_f32(y));
                let y_hi = vcvt_f64_f32(vget_high_f32(y));
                l01 = vaddq_f64(l01, vmulq_f64(a_lo, y_lo));
                l23 = vaddq_f64(l23, vmulq_f64(a_hi, y_hi));
            }
        }
        let mut s = (vgetq_lane_f64::<0>(l01) + vgetq_lane_f64::<1>(l01))
            + (vgetq_lane_f64::<0>(l23) + vgetq_lane_f64::<1>(l23));
        for t in n4 * 4..av.len() {
            s += elem::<OP>(*av.get_unchecked(t), *yv.get_unchecked(t));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The pre-refactor `lane_tile` formulation: segment bounds from the
    /// `k1 = min(k0 + SEG_LEN, dim)` arithmetic instead of `chunks_exact`
    /// + remainder. The restructured scalar kernel must match it bitwise —
    /// this pins the fold boundary the SIMD kernels also reproduce.
    fn lane_tile_k1_bound<const MR: usize>(
        rows: &[&[f32]; MR],
        packed: &[f32],
        op: impl Fn(f32, f32) -> f32 + Copy,
    ) -> [[f64; REF_LANES]; MR] {
        let dim = rows[0].len();
        let mut wide = [[0f64; REF_LANES]; MR];
        let mut k0 = 0usize;
        while k0 < dim {
            let k1 = (k0 + SEG_LEN).min(dim);
            let mut acc = [[0f32; REF_LANES]; MR];
            let seg = &packed[k0 * REF_LANES..k1 * REF_LANES];
            for (k, y) in seg.chunks_exact(REF_LANES).enumerate() {
                for i in 0..MR {
                    let a = rows[i][k0 + k];
                    for (lane, &yv) in acc[i].iter_mut().zip(y) {
                        *lane += op(a, yv);
                    }
                }
            }
            for i in 0..MR {
                for (w, &narrow) in wide[i].iter_mut().zip(&acc[i]) {
                    *w += narrow as f64;
                }
            }
            k0 = k1;
        }
        wide
    }

    fn random_tile(rng: &mut Rng, dim: usize) -> (Vec<f32>, Vec<f32>) {
        let rows: Vec<f32> = (0..4 * dim).map(|_| rng.gaussian() as f32).collect();
        let packed: Vec<f32> = (0..dim * REF_LANES).map(|_| rng.gaussian() as f32).collect();
        (rows, packed)
    }

    #[test]
    fn fold_boundary_pinned_at_segment_edges() {
        let mut rng = Rng::seeded(91);
        for dim in [1, SEG_LEN - 1, SEG_LEN, SEG_LEN + 1, 2 * SEG_LEN, 2 * SEG_LEN + 7] {
            let (rows_raw, packed) = random_tile(&mut rng, dim);
            let rows: [&[f32]; 4] = std::array::from_fn(|i| &rows_raw[i * dim..(i + 1) * dim]);
            let ops: [fn(f32, f32) -> f32; 2] = [|a, y| a * y, |a, y| (a - y).abs()];
            for op in ops {
                let got = lane_tile_scalar::<4>(&rows, &packed, op);
                let want = lane_tile_k1_bound::<4>(&rows, &packed, op);
                assert_eq!(got, want, "fold boundary moved at dim={dim}");
            }
            let rows1: [&[f32]; 1] = [rows[0]];
            let got = lane_tile_scalar::<1>(&rows1, &packed, |a, y| a * y);
            let want = lane_tile_k1_bound::<1>(&rows1, &packed, |a, y| a * y);
            assert_eq!(got, want, "MR=1 fold boundary moved at dim={dim}");
        }
    }

    #[test]
    fn resolve_validates_requests() {
        assert_eq!(resolve(None), Ok(detect()));
        assert_eq!(resolve(Some("auto")), Ok(detect()));
        assert_eq!(resolve(Some("scalar")), Ok(Variant::Scalar));
        assert!(resolve(Some("avx512")).unwrap_err().contains("invalid CORRSH_KERNEL"));
        assert!(resolve(Some("")).unwrap_err().contains("invalid CORRSH_KERNEL"));
        assert!(resolve(Some("Scalar")).unwrap_err().contains("invalid CORRSH_KERNEL"));
        // Forcing the other architecture's variant is a hard error, and
        // forcing this one's succeeds exactly when the CPU supports it.
        #[cfg(target_arch = "x86_64")]
        {
            assert!(resolve(Some("neon")).is_err());
            if detect() == Variant::Avx2 {
                assert_eq!(resolve(Some("avx2")), Ok(Variant::Avx2));
            } else {
                assert!(resolve(Some("avx2")).is_err());
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            assert!(resolve(Some("avx2")).is_err());
            assert_eq!(resolve(Some("neon")), Ok(Variant::Neon));
        }
    }

    #[test]
    fn kernel_info_reports_active_variant() {
        let line = kernel_info();
        assert!(line.contains(&format!("kernel_variant={}", active())));
        assert!(line.contains("seg_len=64"));
    }

    /// Dense tile kernels: detected-variant output must be bitwise equal
    /// to the scalar reference across fold boundaries and all MR widths.
    /// (The full engine-level property lives in tests/dense_tiles.rs.)
    #[test]
    fn dense_simd_tiles_bitwise_equal_scalar() {
        let v = detect();
        let mut rng = Rng::seeded(17);
        for dim in [1, 3, 8, 63, 64, 65, 127, 128, 129, 200] {
            let (rows_raw, packed) = random_tile(&mut rng, dim);
            let rows: [&[f32]; 4] = std::array::from_fn(|i| &rows_raw[i * dim..(i + 1) * dim]);
            assert_eq!(
                dot_tile::<4>(v, &rows, &packed),
                dot_tile::<4>(Variant::Scalar, &rows, &packed),
                "dot dim={dim}"
            );
            assert_eq!(
                l1_tile::<4>(v, &rows, &packed),
                l1_tile::<4>(Variant::Scalar, &rows, &packed),
                "l1 dim={dim}"
            );
            let rows2: [&[f32]; 2] = [rows[0], rows[3]];
            assert_eq!(
                dot_tile::<2>(v, &rows2, &packed),
                dot_tile::<2>(Variant::Scalar, &rows2, &packed),
                "MR=2 dot dim={dim}"
            );
        }
    }

    /// Sparse correction walks: the detected variant must agree bitwise
    /// with the scalar mirror, and (reassociation aside) with a direct
    /// sequential f64 oracle, across supports mixing long runs, short
    /// runs, and isolated indices.
    #[test]
    fn sparse_runs_bitwise_equal_scalar_and_near_oracle() {
        let v = detect();
        let dim = 257;
        let mut rng = Rng::seeded(23);
        for case in 0..40 {
            let scratch: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
            let mut indices: Vec<u32> = Vec::new();
            let mut c = rng.below(4) as u32;
            while (c as usize) < dim {
                // run lengths 1..=24 straddle RUN_MIN on both sides
                let run = 1 + rng.below(24);
                for t in 0..run {
                    if (c as usize + t) < dim {
                        indices.push(c + t as u32);
                    }
                }
                c += (run + 1 + rng.below(9)) as u32;
            }
            let values: Vec<f32> = indices.iter().map(|_| rng.gaussian() as f32).collect();
            for op in 0..3u8 {
                let walk = |variant| match op {
                    0 => sparse_l1_corr(variant, &indices, &values, &scratch),
                    1 => sparse_l2_corr(variant, &indices, &values, &scratch),
                    _ => sparse_dot(variant, &indices, &values, &scratch),
                };
                let got = walk(v);
                let reference = walk(Variant::Scalar);
                assert_eq!(got.to_bits(), reference.to_bits(), "case {case} op {op}");
                let oracle: f64 = indices
                    .iter()
                    .zip(&values)
                    .map(|(&ci, &av)| {
                        let yv = scratch[ci as usize];
                        match op {
                            0 => ((av - yv).abs() - yv.abs()) as f64,
                            1 => {
                                let d = (av - yv) as f64;
                                d * d - yv as f64 * yv as f64
                            }
                            _ => av as f64 * yv as f64,
                        }
                    })
                    .sum();
                let tol = 1e-9 * oracle.abs().max(1.0);
                assert!(
                    (got - oracle).abs() <= tol,
                    "case {case} op {op}: {got} vs oracle {oracle}"
                );
            }
        }
    }

    #[test]
    fn sparse_walk_handles_empty_and_nan() {
        let v = detect();
        assert_eq!(sparse_dot(v, &[], &[], &[1.0, 2.0]), 0.0);
        let scratch = vec![1.0f32; 32];
        let indices: Vec<u32> = (0..16).collect();
        let mut values = vec![0.5f32; 16];
        values[9] = f32::NAN;
        let walks: [fn(Variant, &[u32], &[f32], &[f32]) -> f64; 3] =
            [sparse_l1_corr, sparse_l2_corr, sparse_dot];
        for walk in walks {
            assert!(walk(v, &indices, &values, &scratch).is_nan());
        }
    }
}
