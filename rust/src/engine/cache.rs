//! Keyed cache of prepared engine sessions: `(dataset, metric) →
//! Arc<PreparedEngine>`.
//!
//! The paper's headline is wall-clock speed, and for a service the wall
//! clock starts before the first pull: preparing a `NativeEngine` costs an
//! O(n·d) pass (cosine norms, the f64 squared norms the tiled L2 kernels
//! subtract against, sparse row-reductions) that used to be paid by
//! *every* `medoid`/`stats` request. The cache pays it once per
//! registered dataset; every subsequent query wraps the shared
//! [`PreparedEngine`] via [`NativeEngine::from_prepared`] for free. Hit /
//! miss counters are exported through the server's `metrics` op so
//! "the second query prepared nothing" is observable, not assumed.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::data::Data;
use crate::distance::Metric;
use crate::engine::native::PreparedEngine;
use crate::metrics::Counter;

#[derive(Default)]
pub struct EngineCache {
    entries: Mutex<HashMap<(String, u64, Metric), Arc<PreparedEngine>>>,
    hits: Counter,
    misses: Counter,
    /// NaN pulls banked from evicted sessions, so [`EngineCache::nan_pulls`]
    /// stays monotone across `invalidate`/unregister instead of dropping
    /// the poisoning signal with the offending dataset.
    evicted_nan_pulls: Counter,
}

impl EngineCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the prepared session for `(name, generation, metric)`,
    /// preparing (and caching) it on first use.
    ///
    /// `generation` is the registry's monotone counter for this binding of
    /// `name` to data. Keying on it makes serving stale data impossible
    /// even when a re-register races an in-flight query: the racer can at
    /// worst cache a session under its *old* generation, which no future
    /// lookup asks for (and which the next `invalidate` sweeps out).
    ///
    /// Preparation runs *outside* the map lock so concurrent queries for
    /// other datasets are not serialized behind an O(n·d) pass; if two
    /// threads race on the same cold key, one redundant preparation is
    /// dropped and both get the same cached `Arc`.
    pub fn get_or_prepare(
        &self,
        name: &str,
        generation: u64,
        metric: Metric,
        data: &Arc<Data>,
    ) -> Arc<PreparedEngine> {
        let key = (name.to_string(), generation, metric);
        if let Some(hit) = self.entries.lock().unwrap().get(&key) {
            self.hits.add(1);
            return hit.clone();
        }
        self.misses.add(1);
        let prepared = Arc::new(PreparedEngine::prepare(data.clone(), metric));
        self.entries.lock().unwrap().entry(key).or_insert(prepared).clone()
    }

    /// Drop every cached session for `name` (all generations and metrics).
    /// Called on `unregister` and re-`register` as memory hygiene —
    /// correctness against stale data comes from the generation key. The
    /// evicted sessions' NaN-pull counts are banked first (monotone metric).
    pub fn invalidate(&self, name: &str) {
        self.entries.lock().unwrap().retain(|(n, _, _), p| {
            if n == name {
                self.evicted_nan_pulls.add(p.nan_pulls());
                false
            } else {
                true
            }
        });
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// NaN pulls surfaced across every session this cache has held — live
    /// entries plus counts banked from evicted ones (see
    /// [`PreparedEngine::nan_pulls`]); exported through the server's
    /// `metrics` op so poisoned datasets are observable, not silent, and
    /// the signal survives unregistering the offending dataset.
    pub fn nan_pulls(&self) -> u64 {
        let live: u64 = self.entries.lock().unwrap().values().map(|p| p.nan_pulls()).sum();
        self.evicted_nan_pulls.get() + live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};

    fn toy_data(seed: u64) -> Arc<Data> {
        Arc::new(gaussian::generate(&SynthConfig {
            n: 60,
            dim: 8,
            seed,
            ..Default::default()
        }))
    }

    #[test]
    fn second_lookup_hits_and_shares() {
        let cache = EngineCache::new();
        let data = toy_data(1);
        let a = cache.get_or_prepare("toy", 0, Metric::L2, &data);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.get_or_prepare("toy", 0, Metric::L2, &data);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached session");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn keyed_by_name_and_metric() {
        let cache = EngineCache::new();
        let data = toy_data(2);
        let l2 = cache.get_or_prepare("toy", 0, Metric::L2, &data);
        let l1 = cache.get_or_prepare("toy", 0, Metric::L1, &data);
        let other = cache.get_or_prepare("other", 0, Metric::L2, &data);
        assert!(!Arc::ptr_eq(&l2, &l1));
        assert!(!Arc::ptr_eq(&l2, &other));
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn generations_isolate_rebindings_of_a_name() {
        // The re-register race: a query holding the old binding must never
        // poison lookups for the new one — generations are distinct keys.
        let cache = EngineCache::new();
        let old_data = toy_data(4);
        let new_data = toy_data(5);
        let fresh = cache.get_or_prepare("toy", 1, Metric::L2, &new_data);
        // Late racer caches a session for the superseded generation…
        let stale = cache.get_or_prepare("toy", 0, Metric::L2, &old_data);
        assert!(!Arc::ptr_eq(&fresh, &stale));
        // …and generation-1 lookups still get the fresh session.
        let again = cache.get_or_prepare("toy", 1, Metric::L2, &new_data);
        assert!(Arc::ptr_eq(&fresh, &again));
        assert!(Arc::ptr_eq(again.data(), &new_data));
    }

    #[test]
    fn nan_pulls_survive_eviction() {
        use crate::engine::{NativeEngine, PullEngine};
        let cache = EngineCache::new();
        let mut raw = vec![0.5f32; 20 * 4];
        raw[0] = f32::NAN;
        let data = Arc::new(Data::Dense(crate::data::DenseData::new(20, 4, raw)));
        let prepared = cache.get_or_prepare("bad", 0, Metric::L2, &data);
        let engine = NativeEngine::from_prepared(prepared, 1);
        assert!(engine.pull(0, 1).is_nan());
        assert_eq!(cache.nan_pulls(), 1);
        // Evicting the poisoned dataset must not reset the signal.
        cache.invalidate("bad");
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.nan_pulls(), 1, "nan_pulls went backwards on eviction");
    }

    #[test]
    fn invalidate_clears_all_metrics_for_name() {
        let cache = EngineCache::new();
        let data = toy_data(3);
        cache.get_or_prepare("a", 0, Metric::L1, &data);
        cache.get_or_prepare("a", 1, Metric::L2, &data);
        cache.get_or_prepare("b", 0, Metric::L2, &data);
        cache.invalidate("a");
        assert_eq!(cache.len(), 1);
        // re-fetch of "a" is a miss again (fresh preparation)
        cache.get_or_prepare("a", 1, Metric::L1, &data);
        assert_eq!(cache.misses(), 4);
        assert!(!cache.is_empty());
    }
}
