//! PJRT-backed pull engine: runs the batched pull hot path through the
//! AOT-compiled Pallas/JAX artifacts (L1+L2), via the bucket batch planner.
//!
//! `pull_block` gathers the arm/ref rows into zero-padded bucket-shaped host
//! buffers, executes `chunk_sums` per job, and accumulates the per-arm
//! partial sums. Padded reference rows are masked inside the HLO; padded arm
//! rows are discarded on readback (contract pinned by
//! `python/tests/test_model.py::test_ref_padding_is_exact`).
//!
//! Single `pull`s (used by the stats engine, not the algorithms' hot path)
//! take the scalar native path — a distance computation is the same
//! quantity on either engine; integration tests assert exact agreement.
//!
//! Parity oracle: `tests/pjrt_parity.rs` and the unit test below hold this
//! engine to the *native* engine, whose dense blocks now run the tiled
//! norm-trick kernels (`engine::kernel`). The 2e-4 relative tolerance
//! budgets both sides' f32 kernel rounding (per-tile f32 sums here,
//! segment-folded lanes there); both accumulate cross-tile in f64.

use std::sync::Arc;

use crate::util::error::{Context, Result};

use crate::coordinator::BatchPlanner;
use crate::data::Data;
use crate::distance::Metric;
use crate::engine::PullEngine;
use crate::runtime::Runtime;

pub struct PjrtEngine {
    data: Arc<Data>,
    metric: Metric,
    runtime: Arc<Runtime>,
    planner: BatchPlanner,
    norms: Option<Arc<Vec<f32>>>,
}

impl PjrtEngine {
    /// Fails fast if the manifest has no buckets for (metric, dim).
    pub fn new(data: Arc<Data>, metric: Metric, runtime: Arc<Runtime>) -> Result<Self> {
        let dim = data.dim();
        let buckets = runtime.manifest().buckets(metric, dim);
        let planner = BatchPlanner::new(buckets).with_context(|| {
            format!(
                "no artifacts for metric={metric} dim={dim}; available dims: {:?} (re-run \
                 `make artifacts` with --dims {dim})",
                runtime.manifest().dims(metric)
            )
        })?;
        let norms = match metric {
            Metric::Cosine => Some(Arc::new(data.norms())),
            _ => None,
        };
        Ok(PjrtEngine { data, metric, runtime, planner, norms })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Pre-compile every bucket this engine can use (otherwise compilation
    /// happens lazily on first use and pollutes latency measurements).
    pub fn warmup(&self) -> Result<()> {
        for (a, r) in self.runtime.manifest().buckets(self.metric, self.data.dim()) {
            self.runtime.executable(self.metric, a, r, self.data.dim())?;
        }
        Ok(())
    }
}

impl PullEngine for PjrtEngine {
    fn n(&self) -> usize {
        self.data.n()
    }

    fn dim(&self) -> usize {
        self.data.dim()
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn pull(&self, arm: usize, reference: usize) -> f32 {
        self.data
            .distance(self.metric, arm, reference, self.norms.as_ref().map(|n| n.as_slice()))
    }

    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        out.fill(0.0);
        let dim = self.data.dim();
        let jobs = self.planner.plan(arms.len(), refs.len());
        // Host-side gather buffers, reused across jobs (sized to the largest
        // bucket in the plan).
        let max_a = jobs.iter().map(|j| j.bucket_arms).max().unwrap_or(0);
        let max_r = jobs.iter().map(|j| j.bucket_refs).max().unwrap_or(0);
        let mut xbuf = vec![0f32; max_a * dim];
        let mut ybuf = vec![0f32; max_r * dim];
        let mut mask = vec![0f32; max_r];

        for job in &jobs {
            let exe = self
                .runtime
                .executable(self.metric, job.bucket_arms, job.bucket_refs, dim)
                .expect("planner produced a bucket missing from the manifest");

            let xs = &mut xbuf[..job.bucket_arms * dim];
            xs.fill(0.0);
            for (k, &a) in arms[job.arm_start..job.arm_start + job.arm_len].iter().enumerate() {
                self.data.densify_row_into(a, &mut xs[k * dim..(k + 1) * dim]);
            }
            let ys = &mut ybuf[..job.bucket_refs * dim];
            ys.fill(0.0);
            let ms = &mut mask[..job.bucket_refs];
            ms.fill(0.0);
            for (k, &r) in refs[job.ref_start..job.ref_start + job.ref_len].iter().enumerate() {
                self.data.densify_row_into(r, &mut ys[k * dim..(k + 1) * dim]);
                ms[k] = 1.0;
            }

            let sums = exe.run(xs, ys, ms).expect("pjrt chunk_sums execution failed");
            // Per-job partial sums accumulate in f64 host-side (the artifact
            // output stays f32 per tile, which is 256 refs at most).
            for k in 0..job.arm_len {
                out[job.arm_start + k] += sums[k] as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{mnist, SynthConfig};
    use crate::engine::NativeEngine;
    use crate::util::rng::Rng;

    fn runtime() -> Option<Arc<Runtime>> {
        let p = std::path::Path::new("artifacts");
        if !p.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(Arc::new(Runtime::open(p).unwrap()))
    }

    #[test]
    fn pjrt_block_matches_native() {
        let Some(rt) = runtime() else { return };
        let data = Arc::new(mnist::generate(&SynthConfig {
            n: 300,
            dim: 784,
            seed: 12,
            ..Default::default()
        }));
        let mut rng = Rng::seeded(0);
        for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
            let pjrt = PjrtEngine::new(data.clone(), metric, rt.clone()).unwrap();
            let native = NativeEngine::with_threads(data.clone(), metric, 1);
            let arms: Vec<usize> = rng.sample_without_replacement(300, 100);
            let refs: Vec<usize> = rng.sample_without_replacement(300, 37);
            let mut got = vec![0f64; arms.len()];
            let mut want = vec![0f64; arms.len()];
            pjrt.pull_block(&arms, &refs, &mut got);
            native.pull_block(&arms, &refs, &mut want);
            for k in 0..arms.len() {
                let tol = want[k].abs().max(1.0) * 2e-4;
                assert!(
                    (got[k] - want[k]).abs() < tol,
                    "{metric} arm {}: pjrt {} vs native {}",
                    arms[k],
                    got[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn missing_dim_fails_fast() {
        let Some(rt) = runtime() else { return };
        let data = Arc::new(mnist::generate(&SynthConfig {
            n: 10,
            dim: 100, // no artifacts for dim=100
            seed: 1,
            ..Default::default()
        }));
        assert!(PjrtEngine::new(data, Metric::L2, rt).is_err());
    }
}
