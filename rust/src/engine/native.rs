//! Native CPU pull engine: vectorized dense sweeps / CSR merge-walks,
//! thread-parallel over arms.
//!
//! This is both the wall-clock workhorse for the sparse workloads (which the
//! dense PJRT artifacts don't cover) and the correctness oracle the PJRT
//! engine is integration-tested against.

use std::sync::Arc;

use crate::coordinator::planner;
use crate::data::{Data, ShardedData, SparseData};
use crate::distance::{dense, Metric, SparseRow};
use crate::engine::kernel::{self, DenseRows, DenseTileCtx};
use crate::engine::{simd, PullEngine};
use crate::metrics::Counter;
use crate::util::threads;

/// `√max(0, d²)` that lets NaN through: the sparse L2 corrections can
/// round a true-zero distance slightly negative (clamp), but a NaN from a
/// poisoned row must *propagate* (DESIGN.md §9) — `f64::max(NaN, 0.0)`
/// returns `0.0` in Rust, which would hand the poisoned pair the minimum
/// possible distance and silence the `nan_pulls` detection signal.
#[inline]
fn nan_safe_clamp_sqrt(d2: f64) -> f64 {
    if d2 > 0.0 {
        d2.sqrt()
    } else if d2.is_nan() {
        f64::NAN
    } else {
        0.0
    }
}

/// The amortizable half of a native engine: the dataset plus every
/// precomputation the pull hot paths read (cosine norms, sparse
/// row-reductions). Preparing costs O(n·d); cloning the `Arc` is free —
/// the engine cache ([`crate::engine::EngineCache`]) and the trial runner
/// share one `PreparedEngine` across many queries/trials so repeated
/// queries pay preparation exactly once.
pub struct PreparedEngine {
    data: Arc<Data>,
    metric: Metric,
    /// Precomputed row norms (cosine only).
    norms: Option<Arc<Vec<f32>>>,
    /// Precomputed per-row Σ|v| (sparse ℓ₁) or Σv² (sparse ℓ₂) — lets the
    /// block hot path visit only the *arm's* support against a densified
    /// reference row (see `sparse_block`). f64: these feed the same
    /// cancellation-prone corrections as `corr`, so an f32 chain here
    /// would dominate the error budget the f64 fix bought back.
    row_reduction: Option<Arc<Vec<f64>>>,
    /// f64 squared row norms (dense ℓ₂ only): the tiled block kernels
    /// compute `d² = ‖a‖² + ‖b‖² − 2⟨a,b⟩`, and the norms must not carry
    /// f32 chain error into that subtraction (DESIGN.md §11).
    sq_norms: Option<Arc<Vec<f64>>>,
    /// NaN **results** surfaced by this session's pull paths (poisoned
    /// inputs, e.g. a NaN feature value), counted at each API's output
    /// granularity: one per NaN distance for `pull`/`pull_matrix`, one per
    /// NaN *sum* for `pull_block` (scanning the output is free; per-distance
    /// detection inside the accumulation kernels is not). The metric is a
    /// poisoning *detection signal* — nonzero means NaN flowed through this
    /// session — not a calibrated distance-level count. NaN is still
    /// *propagated* (the bandit selection layer orders it last via
    /// `nan_last`/`total_cmp`) but never silently: the count is exported
    /// through [`NativeEngine::nan_pulls`] and the server's `metrics` op.
    nan_pulls: Counter,
}

impl PreparedEngine {
    /// Run the O(n·d) preparation pass (norms / row-reductions). Resident
    /// data maps per row; sharded data streams one pass per shard on the
    /// worker pool (each shard fetched once, chunk boundaries on shard
    /// boundaries) — same per-row kernels, so the reductions are bitwise
    /// identical to the resident path at any worker count.
    pub fn prepare(data: Arc<Data>, metric: Metric) -> Self {
        let norms = match metric {
            Metric::Cosine => Some(Arc::new(match &*data {
                Data::Sharded(sd) => sharded_norms(sd),
                resident => resident.norms(),
            })),
            _ => None,
        };
        let row_reduction = match (&*data, metric) {
            (Data::Sparse(s), Metric::L1) => Some(Arc::new(
                (0..s.n).map(|i| s.row(i).abs_sum_f64()).collect::<Vec<f64>>(),
            )),
            (Data::Sparse(s), Metric::L2) => Some(Arc::new(
                (0..s.n)
                    .map(|i| s.row(i).values.iter().map(|&v| v as f64 * v as f64).sum())
                    .collect::<Vec<f64>>(),
            )),
            (Data::Sharded(sd), Metric::L1 | Metric::L2) if sd.is_sparse() => {
                Some(Arc::new(sharded_row_reduction(sd, metric)))
            }
            _ => None,
        };
        let sq_norms = match (&*data, metric) {
            (Data::Dense(d), Metric::L2) => Some(Arc::new(
                (0..d.n).map(|i| dense::sqnorm_f64(d.row(i))).collect::<Vec<f64>>(),
            )),
            (Data::Sharded(sd), Metric::L2) if !sd.is_sparse() => {
                Some(Arc::new(sharded_sq_norms(sd)))
            }
            _ => None,
        };
        PreparedEngine { data, metric, norms, row_reduction, sq_norms, nan_pulls: Counter::new() }
    }

    pub fn data(&self) -> &Arc<Data> {
        &self.data
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Precomputed euclidean row norms (cosine sessions only).
    pub fn norms(&self) -> Option<&[f32]> {
        self.norms.as_deref().map(|v| v.as_slice())
    }

    /// Precomputed f64 squared row norms (dense ℓ₂ sessions only).
    pub fn sq_norms(&self) -> Option<&[f64]> {
        self.sq_norms.as_deref().map(|v| v.as_slice())
    }

    /// Precomputed per-row Σ|v| / Σv² (sparse ℓ₁/ℓ₂ sessions only).
    pub fn row_reductions(&self) -> Option<&[f64]> {
        self.row_reduction.as_deref().map(|v| v.as_slice())
    }

    /// NaN results surfaced so far by every engine sharing this session.
    pub fn nan_pulls(&self) -> u64 {
        self.nan_pulls.get()
    }

    /// Order-fixed FNV-1a-64 fingerprint of the prepared session: shape,
    /// metric, every precomputed array (as exact bit patterns), and up to
    /// 16 evenly-spaced data rows. The distributed coordinator cross-checks
    /// it across workers at registration and again on rejoin (DESIGN.md
    /// §15) — the row sample is what still gives content coverage for
    /// metric/data combinations with no precomputed arrays (dense ℓ₁).
    ///
    /// This is a divergence tripwire, not a cryptographic commitment: a
    /// worker serving different data collides only by accident, which is
    /// all the failure mode (mismatched files or generator seeds) needs.
    pub fn digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        let n = self.data.n();
        eat(&(n as u64).to_le_bytes());
        eat(&(self.data.dim() as u64).to_le_bytes());
        eat(self.metric.name().as_bytes());
        if let Some(norms) = self.norms() {
            for &x in norms {
                eat(&x.to_bits().to_le_bytes());
            }
        }
        if let Some(sq) = self.sq_norms() {
            for &x in sq {
                eat(&x.to_bits().to_le_bytes());
            }
        }
        if let Some(rr) = self.row_reductions() {
            for &x in rr {
                eat(&x.to_bits().to_le_bytes());
            }
        }
        let mut row = vec![0f32; self.data.dim()];
        let sample = 16.min(n);
        for k in 0..sample {
            let i = k * n / sample;
            self.data.densify_row_into(i, &mut row);
            eat(&(i as u64).to_le_bytes());
            for &x in &row {
                eat(&x.to_bits().to_le_bytes());
            }
        }
        h
    }
}

/// Shard-streaming cosine norms: one pass per shard on the worker pool.
fn sharded_norms(sd: &ShardedData) -> Vec<f32> {
    let threads = threads::default_threads();
    let mut out = vec![0f32; sd.n()];
    let chunk = planner::shard_aligned_chunk(sd.n(), threads * 2, 1, sd.rows_per_shard());
    threads::parallel_chunks_mut(&mut out, chunk, threads, |start, slot| {
        if sd.is_sparse() {
            sd.for_sparse_rows(start, slot.len(), |i, r| slot[i - start] = r.norm());
        } else {
            sd.for_dense_rows(start, slot.len(), |i, row| slot[i - start] = dense::norm(row));
        }
    });
    out
}

/// Shard-streaming f64 squared norms (dense ℓ₂ norm trick).
fn sharded_sq_norms(sd: &ShardedData) -> Vec<f64> {
    let threads = threads::default_threads();
    let mut out = vec![0f64; sd.n()];
    let chunk = planner::shard_aligned_chunk(sd.n(), threads * 2, 1, sd.rows_per_shard());
    threads::parallel_chunks_mut(&mut out, chunk, threads, |start, slot| {
        sd.for_dense_rows(start, slot.len(), |i, row| {
            slot[i - start] = dense::sqnorm_f64(row)
        });
    });
    out
}

/// Shard-streaming sparse row reductions (Σ|v| for ℓ₁, Σv² for ℓ₂) —
/// the same per-row expressions as the resident arm of `prepare`.
fn sharded_row_reduction(sd: &ShardedData, metric: Metric) -> Vec<f64> {
    let threads = threads::default_threads();
    let mut out = vec![0f64; sd.n()];
    let chunk = planner::shard_aligned_chunk(sd.n(), threads * 2, 1, sd.rows_per_shard());
    threads::parallel_chunks_mut(&mut out, chunk, threads, |start, slot| {
        sd.for_sparse_rows(start, slot.len(), |i, r| {
            slot[i - start] = match metric {
                Metric::L1 => r.abs_sum_f64(),
                _ => r.values.iter().map(|&v| v as f64 * v as f64).sum(),
            };
        });
    });
    out
}

/// Row source for the sparse fast paths: resident CSR or a sparse shard
/// store. The hot loops are written against this, so the densified-
/// reference arithmetic — and therefore every bit of every sum — is
/// shared between backends.
#[derive(Clone, Copy)]
enum SparseRows<'a> {
    Resident(&'a SparseData),
    Sharded(&'a ShardedData),
}

impl SparseRows<'_> {
    #[inline]
    fn dim(&self) -> usize {
        match self {
            SparseRows::Resident(s) => s.dim,
            SparseRows::Sharded(sd) => sd.dim(),
        }
    }

    #[inline]
    fn avg_nnz(&self) -> usize {
        match self {
            SparseRows::Resident(s) => s.avg_nnz(),
            SparseRows::Sharded(sd) => sd.avg_nnz(),
        }
    }

    #[inline]
    fn with_row<R>(&self, i: usize, f: impl FnOnce(SparseRow<'_>) -> R) -> R {
        match self {
            SparseRows::Resident(s) => f(s.row(i)),
            SparseRows::Sharded(sd) => sd.with_sparse_row(i, f),
        }
    }

    /// One per worker: pins the last-touched shard so the per-pair inner
    /// loops don't take the shard-cache lock per access (resident rows
    /// need no pin — the cursor is a no-op there).
    fn cursor(&self) -> SparseRowCursor {
        match self {
            SparseRows::Resident(_) => SparseRowCursor::Resident,
            SparseRows::Sharded(sd) => SparseRowCursor::Sharded(sd.sparse_cursor()),
        }
    }

    #[inline]
    fn with_row_cached<R>(
        &self,
        cur: &mut SparseRowCursor,
        i: usize,
        f: impl FnOnce(SparseRow<'_>) -> R,
    ) -> R {
        match (self, cur) {
            (SparseRows::Resident(s), _) => f(s.row(i)),
            (SparseRows::Sharded(sd), SparseRowCursor::Sharded(c)) => {
                sd.with_sparse_row_cached(c, i, f)
            }
            (SparseRows::Sharded(sd), _) => sd.with_sparse_row(i, f),
        }
    }
}

/// See [`SparseRows::cursor`].
enum SparseRowCursor {
    Resident,
    Sharded(crate::data::store::SparseCursor),
}

pub struct NativeEngine {
    prepared: Arc<PreparedEngine>,
    threads: usize,
}

impl NativeEngine {
    pub fn new(data: Data, metric: Metric) -> Self {
        Self::with_threads(Arc::new(data), metric, threads::default_threads())
    }

    pub fn with_threads(data: Arc<Data>, metric: Metric, threads: usize) -> Self {
        Self::from_prepared(Arc::new(PreparedEngine::prepare(data, metric)), threads)
    }

    /// Wrap an already-prepared session — zero preparation cost. This is
    /// the cached-engine fast path the server uses on every query after
    /// the first.
    pub fn from_prepared(prepared: Arc<PreparedEngine>, threads: usize) -> Self {
        NativeEngine { prepared, threads }
    }

    pub fn data(&self) -> &Arc<Data> {
        &self.prepared.data
    }

    pub fn prepared(&self) -> &Arc<PreparedEngine> {
        &self.prepared
    }

    /// NaN results surfaced by this engine's session (shared across every
    /// engine wrapping the same [`PreparedEngine`]).
    pub fn nan_pulls(&self) -> u64 {
        self.prepared.nan_pulls()
    }

    #[inline]
    fn dist(&self, i: usize, j: usize) -> f32 {
        let p = &*self.prepared;
        p.data.distance(p.metric, i, j, p.norms.as_ref().map(|n| n.as_slice()))
    }

    /// Count NaN sums in a finished block (O(arms) scan — negligible next
    /// to the O(arms·refs·d) distance work it audits).
    fn note_nan_sums(&self, out: &[f64]) {
        let nans = out.iter().filter(|v| v.is_nan()).count();
        if nans > 0 {
            self.prepared.nan_pulls.add(nans as u64);
        }
    }

    fn note_nan_dists(&self, out: &[f32]) {
        let nans = out.iter().filter(|v| v.is_nan()).count();
        if nans > 0 {
            self.prepared.nan_pulls.add(nans as u64);
        }
    }

    /// Sparse block fast path (§Perf optimization #1, EXPERIMENTS.md):
    /// the correlated round structure scores *every* arm against the same
    /// reference set, so each reference row is densified once into an
    /// O(d) scratch and each pull becomes a branchless walk over only the
    /// arm's support — O(nnz_arm) with L1-resident random access, instead
    /// of the O(nnz_a + nnz_b) branchy merge-walk:
    ///
    /// ```text
    /// l1(a,y)  = Σ_{k∈supp(a)} (|a_k−y_k| − |y_k|) + Σ|y|
    /// l2²(a,y) = Σ_{k∈supp(a)} ((a_k−y_k)² − y_k²) + Σy²
    /// cos(a,y) = 1 − (Σ_{k∈supp(a)} a_k·y_k) / (‖a‖‖y‖)
    /// ```
    fn sparse_block(&self, s: SparseRows<'_>, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        let dim = s.dim();
        let work = arms.len() * refs.len();
        // FLOP-scaled cutoff over the *effective* per-pair dim (a sparse
        // pair costs the arm's support walk, not a d-length sweep).
        let threads = threads::plan_threads(self.threads, work, s.avg_nnz());
        let chunk = arms.len().div_ceil(threads.max(1)).max(1);
        let metric = self.prepared.metric;
        let norms = self.prepared.norms.as_deref().map(|v| v.as_slice());
        let redux = self.prepared.row_reduction.as_deref().map(|v| v.as_slice());
        // One dispatch decision per call, shared by every worker: the
        // correction walks (`engine::simd`) vectorize runs of consecutive
        // support indices against the densified reference — gather-free,
        // because within a run both sides are contiguous.
        let variant = simd::active();

        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            let mut scratch = vec![0f32; dim];
            let mut acc = vec![0f64; slot.len()];
            // Per-worker shard pins: the arm loop below touches consecutive
            // arms per ref, so `arm_cur` skips the shard-cache lock for
            // every access inside the pinned shard; `ref_cur` keeps the
            // reference row's shard alive between the densify and
            // un-densify passes (zero-copy on the resident backend).
            let mut arm_cur = s.cursor();
            let mut ref_cur = s.cursor();
            for &j in refs {
                s.with_row_cached(&mut ref_cur, j, |y| {
                    for (&c, &v) in y.indices.iter().zip(y.values) {
                        scratch[c as usize] = v;
                    }
                });
                // The corrections accumulate in f64: the `(av−yv)² − yv²`
                // and `|av−yv| − |yv|` terms cancel almost exactly at
                // large magnitudes, and an f32 running sum re-introduced
                // the chain error the f64 round-sum policy (DESIGN.md §9)
                // exists to exclude. The walks themselves live in
                // `engine::simd` (run-vectorized, variant-dispatched).
                match metric {
                    Metric::L1 => {
                        let y_abs = redux.unwrap()[j];
                        for (k, a) in acc.iter_mut().enumerate() {
                            let corr = s.with_row_cached(&mut arm_cur, arms[start + k], |row| {
                                simd::sparse_l1_corr(variant, row.indices, row.values, &scratch)
                            });
                            *a += corr + y_abs;
                        }
                    }
                    Metric::L2 => {
                        let y_sq = redux.unwrap()[j];
                        for (k, a) in acc.iter_mut().enumerate() {
                            let corr = s.with_row_cached(&mut arm_cur, arms[start + k], |row| {
                                simd::sparse_l2_corr(variant, row.indices, row.values, &scratch)
                            });
                            *a += nan_safe_clamp_sqrt(corr + y_sq);
                        }
                    }
                    Metric::Cosine => {
                        let ny = norms.unwrap()[j];
                        for (k, a) in acc.iter_mut().enumerate() {
                            let arm = arms[start + k];
                            let dot = s.with_row_cached(&mut arm_cur, arm, |row| {
                                simd::sparse_dot(variant, row.indices, row.values, &scratch)
                            });
                            let denom = norms.unwrap()[arm] * ny;
                            *a += if denom <= 1e-24 { 1.0 } else { 1.0 - dot / denom as f64 };
                        }
                    }
                }
                // un-densify (touch only y's support; the pinned ref
                // shard makes this second fetch lock-free)
                s.with_row_cached(&mut ref_cur, j, |y| {
                    for &c in y.indices {
                        scratch[c as usize] = 0.0;
                    }
                });
            }
            for (o, &a) in slot.iter_mut().zip(&acc) {
                *o = a;
            }
        });
    }

    /// Element-writing twin of [`NativeEngine::sparse_block`] (the
    /// stats-engine hot path): same densified-reference walks, same f64
    /// `corr` accumulation, writing `slot[k·m + j]` instead of summing.
    fn sparse_matrix(&self, s: SparseRows<'_>, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        let m = refs.len();
        let dim = s.dim();
        let metric = self.prepared.metric;
        let norms = self.prepared.norms.as_deref().map(|v| v.as_slice());
        let redux = self.prepared.row_reduction.as_deref().map(|v| v.as_slice());
        // Average-nnz FLOP cutoff, same rationale as `sparse_block`.
        let threads = threads::plan_threads(self.threads, out.len(), s.avg_nnz());
        let chunk = (arms.len().div_ceil(threads.max(1)).max(1)) * m;
        let variant = simd::active();
        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            debug_assert_eq!(start % m, 0);
            let arm0 = start / m;
            let n_arms = slot.len() / m;
            let mut scratch = vec![0f32; dim];
            // Per-worker shard pins, same rationale as `sparse_block`.
            let mut arm_cur = s.cursor();
            let mut ref_cur = s.cursor();
            for (j, &r) in refs.iter().enumerate() {
                s.with_row_cached(&mut ref_cur, r, |y| {
                    for (&c, &v) in y.indices.iter().zip(y.values) {
                        scratch[c as usize] = v;
                    }
                });
                for k in 0..n_arms {
                    let arm = arms[arm0 + k];
                    // f64 corrections, same rationale as `sparse_block`:
                    // the terms cancel at large magnitudes and must not
                    // pick up f32 chain error. Same `engine::simd` walks,
                    // so both sparse entry points share every bit.
                    let d = s.with_row_cached(&mut arm_cur, arm, |row| match metric {
                        Metric::L1 => {
                            let corr =
                                simd::sparse_l1_corr(variant, row.indices, row.values, &scratch);
                            (corr + redux.unwrap()[r]) as f32
                        }
                        Metric::L2 => {
                            let corr =
                                simd::sparse_l2_corr(variant, row.indices, row.values, &scratch);
                            nan_safe_clamp_sqrt(corr + redux.unwrap()[r]) as f32
                        }
                        Metric::Cosine => {
                            let dot =
                                simd::sparse_dot(variant, row.indices, row.values, &scratch);
                            let denom = norms.unwrap()[arm] * norms.unwrap()[r];
                            if denom <= 1e-24 {
                                1.0
                            } else {
                                (1.0 - dot / denom as f64) as f32
                            }
                        }
                    });
                    slot[k * m + j] = d;
                }
                s.with_row_cached(&mut ref_cur, r, |y| {
                    for &c in y.indices {
                        scratch[c as usize] = 0.0;
                    }
                });
            }
        });
    }

    /// The dense tile-kernel session view over this engine's precomputed
    /// norms (see [`crate::engine::kernel`]) — resident or sharded rows.
    fn tile_ctx<'a>(&'a self, rows: impl Into<DenseRows<'a>>) -> DenseTileCtx<'a> {
        DenseTileCtx::new(
            rows,
            self.prepared.metric,
            self.prepared.norms.as_deref().map(|v| v.as_slice()),
            self.prepared.sq_norms.as_deref().map(|v| v.as_slice()),
        )
    }

    /// Per-pair scalar reference for [`PullEngine::pull_block`]: one
    /// `dist` call per (arm, ref) pair, f64 sums in reference order. This
    /// is the seed hot path the tiled kernels replaced — kept as the
    /// correctness oracle for the tile layer's property tests and the
    /// old-vs-new baseline in `benches/engine.rs`.
    pub fn pull_block_scalar(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        let threads = threads::plan_threads(self.threads, arms.len() * refs.len(), self.dim());
        let chunk = arms.len().div_ceil(threads.max(1) * 4).max(1);
        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            for (off, o) in slot.iter_mut().enumerate() {
                let a = arms[start + off];
                let mut acc = 0f64; // f64 accumulator: t_r can reach n
                for &r in refs {
                    acc += self.dist(a, r) as f64;
                }
                *o = acc;
            }
        });
        self.note_nan_sums(out);
    }

    /// Per-pair scalar reference for [`PullEngine::pull_matrix`] (see
    /// [`NativeEngine::pull_block_scalar`]).
    pub fn pull_matrix_scalar(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        assert_eq!(arms.len() * refs.len(), out.len());
        let m = refs.len();
        let threads = threads::plan_threads(self.threads, out.len(), self.dim());
        threads::parallel_chunks_mut(out, m.max(1), threads, |start, row| {
            let a = arms[start / m];
            for (j, o) in row.iter_mut().enumerate() {
                *o = self.dist(a, refs[j]);
            }
        });
        self.note_nan_dists(out);
    }
}

impl PullEngine for NativeEngine {
    fn n(&self) -> usize {
        self.prepared.data.n()
    }

    fn dim(&self) -> usize {
        self.prepared.data.dim()
    }

    fn metric(&self) -> Metric {
        self.prepared.metric
    }

    #[inline]
    fn pull(&self, arm: usize, reference: usize) -> f32 {
        let d = self.dist(arm, reference);
        if d.is_nan() {
            self.prepared.nan_pulls.add(1);
        }
        d
    }

    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        // Sparse data takes the densified-reference fast path (~12x on the
        // RNA-Seq geometry — see EXPERIMENTS.md §Perf). Densifying a
        // reference costs O(d), amortized over the arms that read it: only
        // worth it when several arms share the refs (which is exactly the
        // correlated-round shape). Sharded backends run the *same* hot
        // loops through their row sources, so resident and sharded results
        // are bitwise identical (DESIGN.md §12).
        match &*self.prepared.data {
            Data::Sparse(s) if arms.len() >= 4 => {
                self.sparse_block(SparseRows::Resident(s), arms, refs, out);
                self.note_nan_sums(out);
            }
            Data::Sharded(sd) if sd.is_sparse() && arms.len() >= 4 => {
                self.sparse_block(SparseRows::Sharded(sd), arms, refs, out);
                self.note_nan_sums(out);
            }
            // Dense: the tiled kernel layer (packed ref tiles + register
            // micro-tiles, ≥3× the per-pair path on MNIST-like geometry —
            // see DESIGN.md §11). ≥ARM_TILE arms amortizes the packing
            // pass; tiny blocks take the scalar reference path.
            Data::Dense(d) if arms.len() >= kernel::ARM_TILE => {
                let threads = threads::plan_threads(self.threads, arms.len() * refs.len(), d.dim);
                self.tile_ctx(d).block_sums(arms, refs, threads, out);
                self.note_nan_sums(out);
            }
            Data::Sharded(sd) if !sd.is_sparse() && arms.len() >= kernel::ARM_TILE => {
                let threads =
                    threads::plan_threads(self.threads, arms.len() * refs.len(), sd.dim());
                self.tile_ctx(sd).block_sums(arms, refs, threads, out);
                self.note_nan_sums(out);
            }
            _ => self.pull_block_scalar(arms, refs, out),
        }
    }

    fn pull_matrix(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        assert_eq!(arms.len() * refs.len(), out.len());
        match &*self.prepared.data {
            // Same densified-reference trick as sparse_block, writing
            // elements instead of accumulating (stats-engine hot path).
            Data::Sparse(s) if arms.len() >= 4 => {
                self.sparse_matrix(SparseRows::Resident(s), arms, refs, out);
                self.note_nan_dists(out);
            }
            Data::Sharded(sd) if sd.is_sparse() && arms.len() >= 4 => {
                self.sparse_matrix(SparseRows::Sharded(sd), arms, refs, out);
                self.note_nan_dists(out);
            }
            // Dense: same tiled kernel layer as `pull_block`, writing
            // elements instead of accumulating.
            Data::Dense(d) if arms.len() >= kernel::ARM_TILE => {
                let threads = threads::plan_threads(self.threads, out.len(), d.dim);
                self.tile_ctx(d).matrix(arms, refs, threads, out);
                self.note_nan_dists(out);
            }
            Data::Sharded(sd) if !sd.is_sparse() && arms.len() >= kernel::ARM_TILE => {
                let threads = threads::plan_threads(self.threads, out.len(), sd.dim());
                self.tile_ctx(sd).matrix(arms, refs, threads, out);
                self.note_nan_dists(out);
            }
            _ => self.pull_matrix_scalar(arms, refs, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{netflix, rnaseq, SynthConfig};
    use crate::data::DenseData;
    use crate::util::rng::Rng;

    fn engines() -> Vec<(&'static str, NativeEngine)> {
        let cfg = SynthConfig { n: 120, dim: 200, seed: 2, density: 0.05, ..Default::default() };
        vec![
            ("rnaseq-l1", NativeEngine::new(rnaseq::generate(&cfg), Metric::L1)),
            ("netflix-cos", NativeEngine::new(netflix::generate(&cfg), Metric::Cosine)),
        ]
    }

    #[test]
    fn block_equals_sum_of_pulls() {
        let mut rng = Rng::seeded(40);
        for (name, e) in engines() {
            let arms: Vec<usize> = (0..e.n()).filter(|_| rng.chance(0.3)).collect();
            let refs = rng.sample_without_replacement(e.n(), 17);
            let mut out = vec![0f64; arms.len()];
            e.pull_block(&arms, &refs, &mut out);
            for (k, &a) in arms.iter().enumerate() {
                let want: f64 = refs.iter().map(|&r| e.pull(a, r) as f64).sum();
                assert!(
                    (out[k] - want).abs() < 1e-3 * want.abs().max(1.0),
                    "{name}: arm {a}: {} vs {want}",
                    out[k]
                );
            }
        }
    }

    #[test]
    fn block_sums_keep_f64_precision_at_large_magnitude() {
        // Regression for the f32 round-sum bug: distances ~1e7 summed over
        // hundreds of refs lose ≫1e-6 relative precision in f32. The tiled
        // path computes L2 via the norm expansion, so individual distances
        // differ from the direct scalar kernel by f32 rounding (~1e-7
        // relative each); 1e-6 on the sums still fails hard if any f32
        // accumulation sneaks back in (that bug cost ~1e-4).
        let n = 400;
        let dim = 8;
        let mut rng = Rng::seeded(50);
        let raw: Vec<f32> = (0..n * dim).map(|_| (rng.gaussian() * 1e7) as f32).collect();
        let data = Data::Dense(DenseData::new(n, dim, raw));
        let e = NativeEngine::with_threads(Arc::new(data), Metric::L2, 4);
        let arms: Vec<usize> = (0..n).collect();
        let refs: Vec<usize> = (0..n).collect();
        let mut out = vec![0f64; n];
        e.pull_block(&arms, &refs, &mut out);
        for (k, &o) in out.iter().enumerate() {
            let want: f64 = refs.iter().map(|&r| e.pull(k, r) as f64).sum();
            let rel = (o - want).abs() / want.abs().max(1.0);
            assert!(rel < 1e-6, "arm {k}: block {o} vs scalar {want} (rel {rel:.3e})");
        }
        assert_eq!(e.nan_pulls(), 0);
    }

    #[test]
    fn sparse_block_sums_keep_f64_precision_at_large_magnitude() {
        // Companion regression for the sparse fast paths: the per-distance
        // correction `corr` cancels `(av−yv)² − yv²` terms of ~1e14 down
        // to ~1e13, which an f32 running sum cannot survive. Held to an
        // exact f64 oracle over the densified rows.
        use crate::data::SparseData;
        let (n, dim) = (160, 512);
        let mut rng = Rng::seeded(51);
        let rows: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|_| {
                (0..dim as u32)
                    .filter(|_| rng.chance(0.4))
                    .map(|c| (c, (rng.gaussian() * 1e7) as f32))
                    .collect()
            })
            .collect();
        let sp = SparseData::from_rows(n, dim, rows);
        let dense_view = Data::Sparse(sp.clone()).to_dense();
        let e = NativeEngine::with_threads(Arc::new(Data::Sparse(sp)), Metric::L2, 4);
        let arms: Vec<usize> = (0..n).collect();
        let refs: Vec<usize> = (0..n).collect();
        let mut out = vec![0f64; n];
        e.pull_block(&arms, &refs, &mut out);
        let mut mat = vec![0f32; n * n];
        e.pull_matrix(&arms, &refs, &mut mat);
        for (k, &o) in out.iter().enumerate() {
            let mut want = 0f64;
            for (r, &got_elem) in refs.iter().zip(&mat[k * n..(k + 1) * n]) {
                let exact: f64 = dense_view
                    .row(k)
                    .iter()
                    .zip(dense_view.row(*r))
                    .map(|(&a, &b)| {
                        let d = (a - b) as f64;
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt();
                want += exact;
                let rel_elem = ((got_elem as f64) - exact).abs() / exact.abs().max(1.0);
                assert!(
                    rel_elem < 1e-6,
                    "matrix ({k},{r}): {got_elem} vs exact {exact} (rel {rel_elem:.3e})"
                );
            }
            let rel = (o - want).abs() / want.abs().max(1.0);
            assert!(rel < 1e-7, "arm {k}: block {o} vs exact {want} (rel {rel:.3e})");
        }
        assert_eq!(e.nan_pulls(), 0);
    }

    #[test]
    fn dense_tiled_paths_match_scalar_reference() {
        // The engine-level wiring of the tile layer: pull_block /
        // pull_matrix against the seed per-pair reference paths, every
        // metric, arm/ref counts off the tile grid.
        let cfg = SynthConfig { n: 150, dim: 101, seed: 21, ..Default::default() };
        let data = Arc::new(crate::data::synth::gaussian::generate(&cfg));
        let mut rng = Rng::seeded(22);
        for metric in Metric::ALL {
            let e = NativeEngine::with_threads(data.clone(), metric, 4);
            let arms: Vec<usize> = (0..(4 * 13 + 3)).map(|_| rng.below(150)).collect();
            let refs: Vec<usize> = (0..(8 * 4 + 5)).map(|_| rng.below(150)).collect();
            let mut tiled = vec![0f64; arms.len()];
            let mut scalar = vec![0f64; arms.len()];
            e.pull_block(&arms, &refs, &mut tiled);
            e.pull_block_scalar(&arms, &refs, &mut scalar);
            for (k, (&t, &s)) in tiled.iter().zip(&scalar).enumerate() {
                assert!(
                    (t - s).abs() < 1e-5 * s.abs().max(1.0),
                    "{metric} block arm {k}: tiled {t} vs scalar {s}"
                );
            }
            let mut tm = vec![0f32; arms.len() * refs.len()];
            let mut sm = vec![0f32; arms.len() * refs.len()];
            e.pull_matrix(&arms, &refs, &mut tm);
            e.pull_matrix_scalar(&arms, &refs, &mut sm);
            for (p, (&t, &s)) in tm.iter().zip(&sm).enumerate() {
                assert!(
                    (t - s).abs() < 1e-5 * s.abs().max(1.0),
                    "{metric} matrix cell {p}: tiled {t} vs scalar {s}"
                );
            }
        }
    }

    #[test]
    fn nan_inputs_are_counted_not_silent() {
        let mut raw = vec![0.5f32; 20 * 4];
        raw[3 * 4] = f32::NAN; // poison row 3
        let data = Data::Dense(DenseData::new(20, 4, raw));
        let e = NativeEngine::with_threads(Arc::new(data), Metric::L2, 1);
        assert_eq!(e.nan_pulls(), 0);
        assert!(e.pull(3, 0).is_nan());
        assert_eq!(e.nan_pulls(), 1);
        let arms: Vec<usize> = (0..20).collect();
        let refs: Vec<usize> = (0..20).collect();
        let mut out = vec![0f64; 20];
        e.pull_block(&arms, &refs, &mut out);
        // ref 3 participates in every arm's sum, so every sum is NaN.
        assert!(out.iter().all(|v| v.is_nan()));
        assert_eq!(e.nan_pulls(), 1 + 20, "every NaN sum counted");
        let mut m = vec![0f32; 2 * 20];
        e.pull_matrix(&[3, 5], &refs, &mut m);
        assert_eq!(e.nan_pulls(), 21 + 20 + 1, "NaN matrix entries counted");
        // The counter is a session property: a sibling engine over the same
        // PreparedEngine observes the same count.
        let sib = NativeEngine::from_prepared(e.prepared().clone(), 2);
        assert_eq!(sib.nan_pulls(), e.nan_pulls());
    }

    #[test]
    fn sparse_nan_inputs_are_counted_not_silent() {
        // Regression: `f64::max(NaN, 0.0)` is `0.0` in Rust, so the sparse
        // L2 clamp used to launder a poisoned row into distance 0 — the
        // *minimum* possible, which would hand the poisoned row the medoid
        // — with nan_pulls staying 0.
        use crate::data::SparseData;
        let mut rows: Vec<Vec<(u32, f32)>> =
            (0..12).map(|i| vec![(0u32, 1.0 + i as f32), (3, 2.0)]).collect();
        rows[3][0].1 = f32::NAN; // poison row 3
        let sp = SparseData::from_rows(12, 8, rows);
        let e = NativeEngine::with_threads(Arc::new(Data::Sparse(sp)), Metric::L2, 1);
        let arms: Vec<usize> = (0..12).collect();
        let mut out = vec![0f64; 12];
        e.pull_block(&arms, &arms, &mut out);
        assert!(out.iter().all(|v| v.is_nan()), "poisoned ref must taint every sparse L2 sum");
        assert_eq!(e.nan_pulls(), 12, "every NaN sparse sum counted");
        let mut m = vec![0f32; 12 * 12];
        e.pull_matrix(&arms, &arms, &mut m);
        for k in 0..12 {
            assert!(m[k * 12 + 3].is_nan(), "({k},3) must be NaN, not a laundered 0");
            assert!(m[3 * 12 + k].is_nan(), "(3,{k}) must be NaN");
        }
        // row 3 + column 3 minus the (3,3) overlap
        assert_eq!(e.nan_pulls(), 12 + 23, "NaN sparse matrix entries counted");
    }

    #[test]
    fn matrix_matches_pulls() {
        for (name, e) in engines() {
            // both the <4-arm scalar path and the densified fast path
            for arms in [vec![0usize, 5, 11], (0..40).collect::<Vec<_>>()] {
                let refs = [3usize, 9, 40, 77];
                let mut m = vec![0f32; arms.len() * refs.len()];
                e.pull_matrix(&arms, &refs, &mut m);
                for (k, &a) in arms.iter().enumerate() {
                    for (j, &r) in refs.iter().enumerate() {
                        let want = e.pull(a, r);
                        assert!(
                            (m[k * refs.len() + j] - want).abs() < 1e-4 * want.abs().max(1.0),
                            "{name} ({a},{r}): {} vs {want}",
                            m[k * refs.len() + j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prepared_engine_is_shareable() {
        let cfg = SynthConfig { n: 90, dim: 64, seed: 9, density: 0.08, ..Default::default() };
        let data = Arc::new(netflix::generate(&cfg));
        let prepared = Arc::new(PreparedEngine::prepare(data.clone(), Metric::Cosine));
        // Two engines over one preparation must agree with a from-scratch
        // build (same norms, same distances).
        let a = NativeEngine::from_prepared(prepared.clone(), 1);
        let b = NativeEngine::from_prepared(prepared.clone(), 4);
        let fresh = NativeEngine::with_threads(data, Metric::Cosine, 1);
        assert_eq!(prepared.metric(), Metric::Cosine);
        assert_eq!(prepared.data().n(), 90);
        for (i, j) in [(0usize, 1usize), (5, 44), (89, 3)] {
            assert_eq!(a.pull(i, j), fresh.pull(i, j));
            assert_eq!(b.pull(i, j), fresh.pull(i, j));
        }
        // The Arc really is shared, not re-prepared per engine.
        assert!(Arc::ptr_eq(a.prepared(), b.prepared()));
    }

    #[test]
    fn sharded_engines_match_resident_bitwise() {
        // Full-engine contract of the storage layer: the same pull APIs
        // over a shard-backed Data (pinned reader, evicting cache) must be
        // bitwise equal to the resident backends on every metric family.
        use crate::data::store::{write_sharded, ShardedData, StoreOptions};
        let tmp = std::env::temp_dir().join("corrsh-native-sharded-tests");
        let cases: Vec<(&str, Data, Metric)> = vec![
            (
                "dense-l2",
                crate::data::synth::mnist::generate(&SynthConfig {
                    n: 90,
                    dim: 33,
                    seed: 8,
                    ..Default::default()
                }),
                Metric::L2,
            ),
            (
                "dense-cos",
                crate::data::synth::gaussian::generate(&SynthConfig {
                    n: 70,
                    dim: 21,
                    seed: 12,
                    ..Default::default()
                }),
                Metric::Cosine,
            ),
            (
                "sparse-l1",
                rnaseq::generate(&SynthConfig {
                    n: 80,
                    dim: 64,
                    seed: 9,
                    density: 0.15,
                    ..Default::default()
                }),
                Metric::L1,
            ),
            (
                "sparse-cos",
                netflix::generate(&SynthConfig {
                    n: 80,
                    dim: 64,
                    seed: 10,
                    density: 0.1,
                    ..Default::default()
                }),
                Metric::Cosine,
            ),
        ];
        for (name, data, metric) in cases {
            let dir = tmp.join(name);
            let _ = std::fs::remove_dir_all(&dir);
            let manifest = write_sharded(&data, &dir, 13).unwrap();
            let opts = StoreOptions {
                cache_bytes: 1 << 14,
                block_bytes: 1 << 10,
                force_pinned: true,
            };
            let sd = ShardedData::open_with(&manifest, &opts).unwrap();
            let resident = NativeEngine::with_threads(Arc::new(data), metric, 4);
            let sharded = NativeEngine::with_threads(Arc::new(Data::Sharded(sd)), metric, 4);
            let n = resident.n();
            let arms: Vec<usize> = (0..n).collect();
            let refs: Vec<usize> = (0..n / 2).collect();
            let mut a = vec![0f64; n];
            let mut b = vec![0f64; n];
            resident.pull_block(&arms, &refs, &mut a);
            sharded.pull_block(&arms, &refs, &mut b);
            assert_eq!(a, b, "{name}: block sums diverged");
            let mut ma = vec![0f32; n * refs.len()];
            let mut mb = vec![0f32; n * refs.len()];
            resident.pull_matrix(&arms, &refs, &mut ma);
            sharded.pull_matrix(&arms, &refs, &mut mb);
            assert_eq!(ma, mb, "{name}: matrices diverged");
            // singles and small (scalar-path) blocks too
            assert_eq!(resident.pull(3, 7).to_bits(), sharded.pull(3, 7).to_bits(), "{name}");
            let mut sa = vec![0f64; 2];
            let mut sb = vec![0f64; 2];
            resident.pull_block(&[1, 5], &refs, &mut sa);
            sharded.pull_block(&[1, 5], &refs, &mut sb);
            assert_eq!(sa, sb, "{name}: scalar-path block diverged");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = SynthConfig { n: 400, dim: 64, seed: 3, ..Default::default() };
        let data = Arc::new(crate::data::synth::mnist::generate(&cfg));
        let serial = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
        let parallel = NativeEngine::with_threads(data, Metric::L2, 8);
        let arms: Vec<usize> = (0..400).collect();
        let refs: Vec<usize> = (0..100).collect();
        let mut a = vec![0f64; 400];
        let mut b = vec![0f64; 400];
        serial.pull_block(&arms, &refs, &mut a);
        parallel.pull_block(&arms, &refs, &mut b);
        assert_eq!(a, b);
    }
}
