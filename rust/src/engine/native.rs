//! Native CPU pull engine: vectorized dense sweeps / CSR merge-walks,
//! thread-parallel over arms.
//!
//! This is both the wall-clock workhorse for the sparse workloads (which the
//! dense PJRT artifacts don't cover) and the correctness oracle the PJRT
//! engine is integration-tested against.

use std::sync::Arc;

use crate::data::{Data, SparseData};
use crate::distance::Metric;
use crate::engine::PullEngine;
use crate::metrics::Counter;
use crate::util::threads;

/// The amortizable half of a native engine: the dataset plus every
/// precomputation the pull hot paths read (cosine norms, sparse
/// row-reductions). Preparing costs O(n·d); cloning the `Arc` is free —
/// the engine cache ([`crate::engine::EngineCache`]) and the trial runner
/// share one `PreparedEngine` across many queries/trials so repeated
/// queries pay preparation exactly once.
pub struct PreparedEngine {
    data: Arc<Data>,
    metric: Metric,
    /// Precomputed row norms (cosine only).
    norms: Option<Arc<Vec<f32>>>,
    /// Precomputed per-row Σ|v| (sparse ℓ₁) or Σv² (sparse ℓ₂) — lets the
    /// block hot path visit only the *arm's* support against a densified
    /// reference row (see `sparse_block`).
    row_reduction: Option<Arc<Vec<f32>>>,
    /// NaN **results** surfaced by this session's pull paths (poisoned
    /// inputs, e.g. a NaN feature value), counted at each API's output
    /// granularity: one per NaN distance for `pull`/`pull_matrix`, one per
    /// NaN *sum* for `pull_block` (scanning the output is free; per-distance
    /// detection inside the accumulation kernels is not). The metric is a
    /// poisoning *detection signal* — nonzero means NaN flowed through this
    /// session — not a calibrated distance-level count. NaN is still
    /// *propagated* (the bandit selection layer orders it last via
    /// `nan_last`/`total_cmp`) but never silently: the count is exported
    /// through [`NativeEngine::nan_pulls`] and the server's `metrics` op.
    nan_pulls: Counter,
}

impl PreparedEngine {
    /// Run the O(n·d) preparation pass (norms / row-reductions).
    pub fn prepare(data: Arc<Data>, metric: Metric) -> Self {
        let norms = match metric {
            Metric::Cosine => Some(Arc::new(data.norms())),
            _ => None,
        };
        let row_reduction = match (&*data, metric) {
            (Data::Sparse(s), Metric::L1) => Some(Arc::new(
                (0..s.n).map(|i| s.row(i).abs_sum()).collect::<Vec<f32>>(),
            )),
            (Data::Sparse(s), Metric::L2) => Some(Arc::new(
                (0..s.n)
                    .map(|i| s.row(i).values.iter().map(|v| v * v).sum())
                    .collect::<Vec<f32>>(),
            )),
            _ => None,
        };
        PreparedEngine { data, metric, norms, row_reduction, nan_pulls: Counter::new() }
    }

    pub fn data(&self) -> &Arc<Data> {
        &self.data
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// NaN results surfaced so far by every engine sharing this session.
    pub fn nan_pulls(&self) -> u64 {
        self.nan_pulls.get()
    }
}

pub struct NativeEngine {
    prepared: Arc<PreparedEngine>,
    threads: usize,
}

impl NativeEngine {
    pub fn new(data: Data, metric: Metric) -> Self {
        Self::with_threads(Arc::new(data), metric, threads::default_threads())
    }

    pub fn with_threads(data: Arc<Data>, metric: Metric, threads: usize) -> Self {
        Self::from_prepared(Arc::new(PreparedEngine::prepare(data, metric)), threads)
    }

    /// Wrap an already-prepared session — zero preparation cost. This is
    /// the cached-engine fast path the server uses on every query after
    /// the first.
    pub fn from_prepared(prepared: Arc<PreparedEngine>, threads: usize) -> Self {
        NativeEngine { prepared, threads }
    }

    pub fn data(&self) -> &Arc<Data> {
        &self.prepared.data
    }

    pub fn prepared(&self) -> &Arc<PreparedEngine> {
        &self.prepared
    }

    /// NaN results surfaced by this engine's session (shared across every
    /// engine wrapping the same [`PreparedEngine`]).
    pub fn nan_pulls(&self) -> u64 {
        self.prepared.nan_pulls()
    }

    #[inline]
    fn dist(&self, i: usize, j: usize) -> f32 {
        let p = &*self.prepared;
        p.data.distance(p.metric, i, j, p.norms.as_ref().map(|n| n.as_slice()))
    }

    /// Count NaN sums in a finished block (O(arms) scan — negligible next
    /// to the O(arms·refs·d) distance work it audits).
    fn note_nan_sums(&self, out: &[f64]) {
        let nans = out.iter().filter(|v| v.is_nan()).count();
        if nans > 0 {
            self.prepared.nan_pulls.add(nans as u64);
        }
    }

    fn note_nan_dists(&self, out: &[f32]) {
        let nans = out.iter().filter(|v| v.is_nan()).count();
        if nans > 0 {
            self.prepared.nan_pulls.add(nans as u64);
        }
    }

    /// Sparse block fast path (§Perf optimization #1, EXPERIMENTS.md):
    /// the correlated round structure scores *every* arm against the same
    /// reference set, so each reference row is densified once into an
    /// O(d) scratch and each pull becomes a branchless walk over only the
    /// arm's support — O(nnz_arm) with L1-resident random access, instead
    /// of the O(nnz_a + nnz_b) branchy merge-walk:
    ///
    /// ```text
    /// l1(a,y)  = Σ_{k∈supp(a)} (|a_k−y_k| − |y_k|) + Σ|y|
    /// l2²(a,y) = Σ_{k∈supp(a)} ((a_k−y_k)² − y_k²) + Σy²
    /// cos(a,y) = 1 − (Σ_{k∈supp(a)} a_k·y_k) / (‖a‖‖y‖)
    /// ```
    fn sparse_block(&self, s: &SparseData, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        let dim = s.dim;
        let work = arms.len() * refs.len();
        let threads = if work < 4096 { 1 } else { self.threads };
        let chunk = arms.len().div_ceil(threads.max(1)).max(1);
        let metric = self.prepared.metric;
        let norms = self.prepared.norms.as_deref().map(|v| v.as_slice());
        let redux = self.prepared.row_reduction.as_deref().map(|v| v.as_slice());

        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            let mut scratch = vec![0f32; dim];
            let mut acc = vec![0f64; slot.len()];
            for &j in refs {
                let y = s.row(j);
                for (&c, &v) in y.indices.iter().zip(y.values) {
                    scratch[c as usize] = v;
                }
                match metric {
                    Metric::L1 => {
                        let y_abs = redux.unwrap()[j] as f64;
                        for (k, a) in acc.iter_mut().enumerate() {
                            let row = s.row(arms[start + k]);
                            let mut corr = 0f32;
                            for (&c, &av) in row.indices.iter().zip(row.values) {
                                let yv = scratch[c as usize];
                                corr += (av - yv).abs() - yv.abs();
                            }
                            *a += corr as f64 + y_abs;
                        }
                    }
                    Metric::L2 => {
                        let y_sq = redux.unwrap()[j] as f64;
                        for (k, a) in acc.iter_mut().enumerate() {
                            let row = s.row(arms[start + k]);
                            let mut corr = 0f32;
                            for (&c, &av) in row.indices.iter().zip(row.values) {
                                let yv = scratch[c as usize];
                                let d = av - yv;
                                corr += d * d - yv * yv;
                            }
                            *a += (corr as f64 + y_sq).max(0.0).sqrt();
                        }
                    }
                    Metric::Cosine => {
                        let ny = norms.unwrap()[j];
                        for (k, a) in acc.iter_mut().enumerate() {
                            let arm = arms[start + k];
                            let row = s.row(arm);
                            let mut dot = 0f32;
                            for (&c, &av) in row.indices.iter().zip(row.values) {
                                dot += av * scratch[c as usize];
                            }
                            let denom = norms.unwrap()[arm] * ny;
                            *a += if denom <= 1e-24 { 1.0 } else { (1.0 - dot / denom) as f64 };
                        }
                    }
                }
                // un-densify (touch only y's support)
                for &c in y.indices {
                    scratch[c as usize] = 0.0;
                }
            }
            for (o, &a) in slot.iter_mut().zip(&acc) {
                *o = a;
            }
        });
    }
}

impl PullEngine for NativeEngine {
    fn n(&self) -> usize {
        self.prepared.data.n()
    }

    fn dim(&self) -> usize {
        self.prepared.data.dim()
    }

    fn metric(&self) -> Metric {
        self.prepared.metric
    }

    #[inline]
    fn pull(&self, arm: usize, reference: usize) -> f32 {
        let d = self.dist(arm, reference);
        if d.is_nan() {
            self.prepared.nan_pulls.add(1);
        }
        d
    }

    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        // Sparse data takes the densified-reference fast path (~12x on the
        // RNA-Seq geometry — see EXPERIMENTS.md §Perf). Densifying a
        // reference costs O(d), amortized over the arms that read it: only
        // worth it when several arms share the refs (which is exactly the
        // correlated-round shape).
        if let Data::Sparse(s) = &*self.prepared.data {
            if arms.len() >= 4 {
                self.sparse_block(s, arms, refs, out);
                self.note_nan_sums(out);
                return;
            }
        }
        // Dense: parallel over arms, refs swept innermost so rows stay
        // cache-resident.
        let work = arms.len() * refs.len();
        let threads = if work < 4096 { 1 } else { self.threads };
        let chunk = arms.len().div_ceil(threads.max(1) * 4).max(1);
        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            for (off, o) in slot.iter_mut().enumerate() {
                let a = arms[start + off];
                let mut acc = 0f64; // f64 accumulator: t_r can reach n
                for &r in refs {
                    acc += self.dist(a, r) as f64;
                }
                *o = acc;
            }
        });
        self.note_nan_sums(out);
    }

    fn pull_matrix(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        assert_eq!(arms.len() * refs.len(), out.len());
        let m = refs.len();
        // Same densified-reference trick as sparse_block, writing elements
        // instead of accumulating (stats-engine hot path, §Perf).
        if let (Data::Sparse(s), true) = (&*self.prepared.data, arms.len() >= 4) {
            let dim = s.dim;
            let metric = self.prepared.metric;
            let norms = self.prepared.norms.as_deref().map(|v| v.as_slice());
            let redux = self.prepared.row_reduction.as_deref().map(|v| v.as_slice());
            let threads = if out.len() < 4096 { 1 } else { self.threads };
            let chunk = (arms.len().div_ceil(threads.max(1)).max(1)) * m;
            threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
                debug_assert_eq!(start % m, 0);
                let arm0 = start / m;
                let n_arms = slot.len() / m;
                let mut scratch = vec![0f32; dim];
                for (j, &r) in refs.iter().enumerate() {
                    let y = s.row(r);
                    for (&c, &v) in y.indices.iter().zip(y.values) {
                        scratch[c as usize] = v;
                    }
                    for k in 0..n_arms {
                        let arm = arms[arm0 + k];
                        let row = s.row(arm);
                        let mut corr = 0f32;
                        let d = match metric {
                            Metric::L1 => {
                                for (&c, &av) in row.indices.iter().zip(row.values) {
                                    let yv = scratch[c as usize];
                                    corr += (av - yv).abs() - yv.abs();
                                }
                                corr + redux.unwrap()[r]
                            }
                            Metric::L2 => {
                                for (&c, &av) in row.indices.iter().zip(row.values) {
                                    let yv = scratch[c as usize];
                                    let dd = av - yv;
                                    corr += dd * dd - yv * yv;
                                }
                                (corr + redux.unwrap()[r]).max(0.0).sqrt()
                            }
                            Metric::Cosine => {
                                for (&c, &av) in row.indices.iter().zip(row.values) {
                                    corr += av * scratch[c as usize];
                                }
                                let denom = norms.unwrap()[arm] * norms.unwrap()[r];
                                if denom <= 1e-24 {
                                    1.0
                                } else {
                                    1.0 - corr / denom
                                }
                            }
                        };
                        slot[k * m + j] = d;
                    }
                    for &c in y.indices {
                        scratch[c as usize] = 0.0;
                    }
                }
            });
            self.note_nan_dists(out);
            return;
        }
        let threads = if out.len() < 4096 { 1 } else { self.threads };
        threads::parallel_chunks_mut(out, m, threads, |start, row| {
            let a = arms[start / m];
            for (j, o) in row.iter_mut().enumerate() {
                *o = self.dist(a, refs[j]);
            }
        });
        self.note_nan_dists(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{netflix, rnaseq, SynthConfig};
    use crate::data::DenseData;
    use crate::util::rng::Rng;

    fn engines() -> Vec<(&'static str, NativeEngine)> {
        let cfg = SynthConfig { n: 120, dim: 200, seed: 2, density: 0.05, ..Default::default() };
        vec![
            ("rnaseq-l1", NativeEngine::new(rnaseq::generate(&cfg), Metric::L1)),
            ("netflix-cos", NativeEngine::new(netflix::generate(&cfg), Metric::Cosine)),
        ]
    }

    #[test]
    fn block_equals_sum_of_pulls() {
        let mut rng = Rng::seeded(40);
        for (name, e) in engines() {
            let arms: Vec<usize> = (0..e.n()).filter(|_| rng.chance(0.3)).collect();
            let refs = rng.sample_without_replacement(e.n(), 17);
            let mut out = vec![0f64; arms.len()];
            e.pull_block(&arms, &refs, &mut out);
            for (k, &a) in arms.iter().enumerate() {
                let want: f64 = refs.iter().map(|&r| e.pull(a, r) as f64).sum();
                assert!(
                    (out[k] - want).abs() < 1e-3 * want.abs().max(1.0),
                    "{name}: arm {a}: {} vs {want}",
                    out[k]
                );
            }
        }
    }

    #[test]
    fn block_sums_keep_f64_precision_at_large_magnitude() {
        // Regression for the f32 round-sum bug: distances ~1e7 summed over
        // hundreds of refs lose ≫1e-6 relative precision in f32.
        let n = 400;
        let dim = 8;
        let mut rng = Rng::seeded(50);
        let raw: Vec<f32> = (0..n * dim).map(|_| (rng.gaussian() * 1e7) as f32).collect();
        let data = Data::Dense(DenseData::new(n, dim, raw));
        let e = NativeEngine::with_threads(Arc::new(data), Metric::L2, 4);
        let arms: Vec<usize> = (0..n).collect();
        let refs: Vec<usize> = (0..n).collect();
        let mut out = vec![0f64; n];
        e.pull_block(&arms, &refs, &mut out);
        for (k, &o) in out.iter().enumerate() {
            let want: f64 = refs.iter().map(|&r| e.pull(k, r) as f64).sum();
            let rel = (o - want).abs() / want.abs().max(1.0);
            assert!(rel < 1e-9, "arm {k}: block {o} vs scalar {want} (rel {rel:.3e})");
        }
        assert_eq!(e.nan_pulls(), 0);
    }

    #[test]
    fn nan_inputs_are_counted_not_silent() {
        let mut raw = vec![0.5f32; 20 * 4];
        raw[3 * 4] = f32::NAN; // poison row 3
        let data = Data::Dense(DenseData::new(20, 4, raw));
        let e = NativeEngine::with_threads(Arc::new(data), Metric::L2, 1);
        assert_eq!(e.nan_pulls(), 0);
        assert!(e.pull(3, 0).is_nan());
        assert_eq!(e.nan_pulls(), 1);
        let arms: Vec<usize> = (0..20).collect();
        let refs: Vec<usize> = (0..20).collect();
        let mut out = vec![0f64; 20];
        e.pull_block(&arms, &refs, &mut out);
        // ref 3 participates in every arm's sum, so every sum is NaN.
        assert!(out.iter().all(|v| v.is_nan()));
        assert_eq!(e.nan_pulls(), 1 + 20, "every NaN sum counted");
        let mut m = vec![0f32; 2 * 20];
        e.pull_matrix(&[3, 5], &refs, &mut m);
        assert_eq!(e.nan_pulls(), 21 + 20 + 1, "NaN matrix entries counted");
        // The counter is a session property: a sibling engine over the same
        // PreparedEngine observes the same count.
        let sib = NativeEngine::from_prepared(e.prepared().clone(), 2);
        assert_eq!(sib.nan_pulls(), e.nan_pulls());
    }

    #[test]
    fn matrix_matches_pulls() {
        for (name, e) in engines() {
            // both the <4-arm scalar path and the densified fast path
            for arms in [vec![0usize, 5, 11], (0..40).collect::<Vec<_>>()] {
                let refs = [3usize, 9, 40, 77];
                let mut m = vec![0f32; arms.len() * refs.len()];
                e.pull_matrix(&arms, &refs, &mut m);
                for (k, &a) in arms.iter().enumerate() {
                    for (j, &r) in refs.iter().enumerate() {
                        let want = e.pull(a, r);
                        assert!(
                            (m[k * refs.len() + j] - want).abs() < 1e-4 * want.abs().max(1.0),
                            "{name} ({a},{r}): {} vs {want}",
                            m[k * refs.len() + j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prepared_engine_is_shareable() {
        let cfg = SynthConfig { n: 90, dim: 64, seed: 9, density: 0.08, ..Default::default() };
        let data = Arc::new(netflix::generate(&cfg));
        let prepared = Arc::new(PreparedEngine::prepare(data.clone(), Metric::Cosine));
        // Two engines over one preparation must agree with a from-scratch
        // build (same norms, same distances).
        let a = NativeEngine::from_prepared(prepared.clone(), 1);
        let b = NativeEngine::from_prepared(prepared.clone(), 4);
        let fresh = NativeEngine::with_threads(data, Metric::Cosine, 1);
        assert_eq!(prepared.metric(), Metric::Cosine);
        assert_eq!(prepared.data().n(), 90);
        for (i, j) in [(0usize, 1usize), (5, 44), (89, 3)] {
            assert_eq!(a.pull(i, j), fresh.pull(i, j));
            assert_eq!(b.pull(i, j), fresh.pull(i, j));
        }
        // The Arc really is shared, not re-prepared per engine.
        assert!(Arc::ptr_eq(a.prepared(), b.prepared()));
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = SynthConfig { n: 400, dim: 64, seed: 3, ..Default::default() };
        let data = Arc::new(crate::data::synth::mnist::generate(&cfg));
        let serial = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
        let parallel = NativeEngine::with_threads(data, Metric::L2, 8);
        let arms: Vec<usize> = (0..400).collect();
        let refs: Vec<usize> = (0..100).collect();
        let mut a = vec![0f64; 400];
        let mut b = vec![0f64; 400];
        serial.pull_block(&arms, &refs, &mut a);
        parallel.pull_block(&arms, &refs, &mut b);
        assert_eq!(a, b);
    }
}
