//! Distributed pull engine: fan `pull_block`/`pull_matrix` out to worker
//! processes and reduce the partial sums exactly (DESIGN.md §15).
//!
//! Workers are plain `corrsh` servers (the `corrsh worker` mode is a shape
//! preset, not a different binary) speaking protocol v2 over the same
//! newline-framed JSON the service uses, so the coordinator's channel layer
//! is [`proto::Framer`] reused verbatim. Each worker registers the **full
//! dataset** (the coordinator forwards its own `register` params and
//! cross-checks the [`PreparedEngine::digest`] fingerprint), which is what
//! makes failure handling simple: any worker can compute any segment, so a
//! death re-dispatches row ranges without data movement.
//!
//! # Exact reduction
//!
//! f64 addition is not associative, so "split refs across workers and add
//! the partials" would change results with the worker count. Instead the
//! reference axis is cut into a **canonical segment grid**
//! ([`Placement`]) that depends only on the dataset and the configured
//! segment count. Workers return one f64 partial per (arm, segment) —
//! computed by their local [`NativeEngine::pull_block`] over the segment's
//! refs in the caller's order — and the coordinator folds segments in
//! ascending canonical order. Summation boundaries and fold order are both
//! worker-count-independent, so the reduced sums are **bitwise identical**
//! across worker counts {1, 2, N} and across any failure/re-dispatch
//! history. Partials travel as f64 *bit patterns* (see [`bits_value`]), so
//! NaN poisoning and signed zeros survive JSON.
//!
//! # Failure handling
//!
//! One `worker.pull` per involved worker per block: write all requests,
//! read responses in worker-index order. A channel error, read timeout, or
//! malformed/`ok:false` response marks the worker dead and hands its
//! segment list to the [`Outstanding`] tracker for re-dispatch to the first
//! surviving worker; ownership is then rebalanced for subsequent blocks.
//! Dead workers are probed again at each block entry and rejoin (with the
//! same digest handshake) when their process comes back. Pull accounting
//! only counts *absorbed* responses, so a block's reported pulls equal
//! `|arms| · |refs|` no matter how many re-dispatches it took.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::dispatch::{Outstanding, Placement};
use crate::distance::Metric;
use crate::engine::PullEngine;
use crate::server::proto::{Frame, Framer};
use crate::util::error::Context;
use crate::util::json::{self, Value};

/// Shape of the distributed session.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Canonical reduction segments (clamped up to the worker count at
    /// connect). More segments = finer re-dispatch granularity; the grid is
    /// frozen per dataset, so this must not change between runs that are
    /// expected to agree bitwise.
    pub segments: usize,
    /// Rows per shard of the served manifest (0 = resident data): segment
    /// boundaries land on shard boundaries when possible.
    pub shard_rows: usize,
    /// Read deadline for `register`/`worker.pull` responses — generous,
    /// because it must cover the worker-side compute of a whole round.
    pub request_timeout_ms: u64,
    /// Deadline for connect probes and `worker.health` pings.
    pub health_timeout_ms: u64,
    /// Channel frame cap for worker responses (a round 0 matrix pull over a
    /// big segment is far larger than a service request).
    pub max_response_bytes: usize,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            segments: 8,
            shard_rows: 0,
            request_timeout_ms: 120_000,
            health_timeout_ms: 2_000,
            max_response_bytes: 1 << 30,
        }
    }
}

/// Lossless JSON encoding of an f64/u64 bit pattern: values up to 2⁵³ ride
/// as JSON numbers (exact in the parser's f64), wider ones as decimal
/// strings — `Value::as_u64` accepts both. The *bits* travel, never the
/// float, so NaN, infinities and signed zeros cross the wire intact.
pub fn bits_value(bits: u64) -> Value {
    if bits <= (1u64 << 53) {
        Value::Num(bits as f64)
    } else {
        Value::Str(bits.to_string())
    }
}

/// One worker channel: a blocking TCP stream plus the shared line framer.
struct Conn {
    stream: TcpStream,
    framer: Framer,
    next_id: u64,
    buf: Vec<u8>,
}

impl Conn {
    fn open(endpoint: &str, cfg: &DistConfig) -> crate::Result<Conn> {
        let addr: SocketAddr = endpoint
            .to_socket_addrs()
            .with_context(|| format!("resolve worker endpoint {endpoint}"))?
            .next()
            .with_context(|| format!("worker endpoint {endpoint} resolves to nothing"))?;
        let stream = TcpStream::connect_timeout(
            &addr,
            Duration::from_millis(cfg.health_timeout_ms.max(1)),
        )
        .with_context(|| format!("connect worker {endpoint}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_millis(cfg.request_timeout_ms.max(1))))
            .with_context(|| format!("set read timeout on worker {endpoint}"))?;
        Ok(Conn {
            stream,
            framer: Framer::new(cfg.max_response_bytes),
            next_id: 1,
            buf: vec![0u8; 64 * 1024],
        })
    }

    /// Write one v2 request line; returns its id for [`Conn::recv`].
    fn send(&mut self, op: &str, params: Value) -> crate::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Value::from_pairs(vec![
            ("v", 2usize.into()),
            ("id", id.into()),
            ("op", op.into()),
            ("params", params),
        ]);
        let mut line = json::to_string(&req);
        line.push('\n');
        self.stream.write_all(line.as_bytes()).context("write to worker")?;
        Ok(id)
    }

    /// Read frames until the final response for `id`; streamed partials
    /// (`"partial":true`) are skipped. Returns the envelope's `result`.
    fn recv(&mut self, id: u64) -> crate::Result<Value> {
        loop {
            while let Some(frame) = self.framer.next_frame() {
                let line = match frame {
                    Frame::Line(l) => l,
                    Frame::Oversized { len } => {
                        crate::bail!("worker response oversized ({len} bytes)")
                    }
                    Frame::Invalid => crate::bail!("invalid frame from worker"),
                };
                let v = match json::parse(&line) {
                    Ok(v) => v,
                    Err(e) => crate::bail!("worker sent unparseable JSON: {e}"),
                };
                if v.get("id").as_u64() != Some(id)
                    || v.get("partial").as_bool() == Some(true)
                {
                    continue;
                }
                return match v.get("ok").as_bool() {
                    Some(true) => Ok(v.get("result").clone()),
                    _ => crate::bail!(
                        "worker error: {}",
                        v.get("error").get("message").as_str().unwrap_or("unknown")
                    ),
                };
            }
            let n = self.stream.read(&mut self.buf).context("read from worker")?;
            crate::ensure!(n > 0, "worker closed the connection");
            self.framer.push(&self.buf[..n]);
        }
    }

    fn rpc(&mut self, op: &str, params: Value) -> crate::Result<Value> {
        let id = self.send(op, params)?;
        self.recv(id)
    }
}

const LATENCY_RING: usize = 512;

struct Worker {
    endpoint: String,
    conn: Option<Conn>,
    pulls: u64,
    restarts: u64,
    latencies_ms: Vec<f64>,
    lat_pos: usize,
}

impl Worker {
    fn record_latency(&mut self, ms: f64) {
        if self.latencies_ms.len() < LATENCY_RING {
            self.latencies_ms.push(ms);
        } else {
            self.latencies_ms[self.lat_pos] = ms;
            self.lat_pos = (self.lat_pos + 1) % LATENCY_RING;
        }
    }

    fn p99_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(f64::total_cmp);
        v[((v.len() * 99).div_ceil(100) - 1).min(v.len() - 1)]
    }
}

/// Per-worker status snapshot (the `metrics` op's `workers` rows).
#[derive(Clone, Debug)]
pub struct WorkerRow {
    pub endpoint: String,
    pub alive: bool,
    pub pulls: u64,
    pub in_flight: usize,
    pub restarts: u64,
    pub p99_ms: f64,
}

struct Inner {
    workers: Vec<Worker>,
    placement: Placement,
    outstanding: Outstanding,
}

/// Gathered per-segment bit patterns for one block.
struct Gathered {
    /// Positions into `refs`, per canonical segment (order-preserving).
    groups: Vec<Vec<usize>>,
    /// Per segment: arm-major bit patterns (block: one f64 per arm;
    /// matrix: `|arms| × |group|` f32 bits widened to u64).
    bits: Vec<Vec<u64>>,
}

/// [`PullEngine`] over N worker processes with exact canonical reduction.
pub struct DistributedEngine {
    dataset: String,
    /// Forwarded `register` params, re-sent verbatim when a worker rejoins.
    register: Value,
    n: usize,
    dim: usize,
    metric: Metric,
    digest: u64,
    cfg: DistConfig,
    inner: Mutex<Inner>,
    remote_pulls: AtomicU64,
    redispatches: AtomicU64,
    /// First gather failure since the last [`Self::take_failure`] call.
    /// `PullEngine::pull_block`/`pull_matrix` return no `Result`, so a
    /// total-fleet loss mid-run is recorded here (and the outputs
    /// zero-filled) instead of panicking through the bandit loop; the
    /// `medoid` op checks this after the run and fails the request.
    gather_failure: Mutex<Option<String>>,
}

impl DistributedEngine {
    /// Connect every endpoint, forward the dataset registration, and
    /// cross-check the prepared-session digests: all workers must serve
    /// bit-identical data or the session is refused outright — a silently
    /// divergent worker would otherwise corrupt sums only on *its*
    /// segments, the worst kind of wrong answer.
    pub fn connect(
        endpoints: &[String],
        dataset: &str,
        register: &Value,
        cfg: DistConfig,
    ) -> crate::Result<Self> {
        crate::ensure!(!endpoints.is_empty(), "distributed engine needs at least one worker");
        let mut workers = Vec::with_capacity(endpoints.len());
        let mut shape: Option<(usize, usize, Metric, u64)> = None;
        for ep in endpoints {
            let mut conn = Conn::open(ep, &cfg)?;
            let (n, dim, metric, digest) = Self::handshake(&mut conn, dataset, register)
                .with_context(|| format!("register dataset {dataset:?} on worker {ep}"))?;
            if let Some((n0, dim0, m0, d0)) = shape {
                crate::ensure!(
                    (n, dim, metric) == (n0, dim0, m0),
                    "worker {ep} sees a different dataset: n={n} dim={dim} metric={metric} \
                     (expected n={n0} dim={dim0} metric={m0})"
                );
                crate::ensure!(
                    digest == d0,
                    "worker {ep} prepared a divergent session: digest {digest:#018x} != \
                     {d0:#018x} — all workers must serve identical data"
                );
            } else {
                shape = Some((n, dim, metric, digest));
            }
            workers.push(Worker {
                endpoint: ep.clone(),
                conn: Some(conn),
                pulls: 0,
                restarts: 0,
                latencies_ms: Vec::new(),
                lat_pos: 0,
            });
        }
        // The ensure! above guarantees at least one worker handshake ran.
        let (n, dim, metric, digest) =
            shape.context("no worker completed the registration handshake")?;
        let mut placement = Placement::new(n, cfg.segments.max(workers.len()), cfg.shard_rows)?;
        placement.assign(&vec![true; workers.len()])?;
        let outstanding = Outstanding::new(workers.len());
        Ok(DistributedEngine {
            dataset: dataset.to_string(),
            register: register.clone(),
            n,
            dim,
            metric,
            digest,
            cfg,
            inner: Mutex::new(Inner { workers, placement, outstanding }),
            remote_pulls: AtomicU64::new(0),
            redispatches: AtomicU64::new(0),
            gather_failure: Mutex::new(None),
        })
    }

    fn handshake(
        conn: &mut Conn,
        dataset: &str,
        register: &Value,
    ) -> crate::Result<(usize, usize, Metric, u64)> {
        conn.rpc("register", register.clone())?;
        let prep =
            conn.rpc("worker.prepare", Value::from_pairs(vec![("dataset", dataset.into())]))?;
        let n = prep.get("n").as_usize().context("worker.prepare: missing n")?;
        let dim = prep.get("dim").as_usize().context("worker.prepare: missing dim")?;
        let metric: Metric =
            prep.get("metric").as_str().context("worker.prepare: missing metric")?.parse()?;
        let digest = prep.get("digest").as_u64().context("worker.prepare: missing digest")?;
        Ok((n, dim, metric, digest))
    }

    /// Total pulls reported by worker responses (the report frames the
    /// budget ledger aggregates). Monotone; only absorbed responses count.
    pub fn remote_pulls(&self) -> u64 {
        self.remote_pulls.load(Ordering::Relaxed)
    }

    /// Re-dispatch events survived so far (one per failed request handed to
    /// a survivor).
    pub fn redispatches(&self) -> u64 {
        self.redispatches.load(Ordering::Relaxed)
    }

    /// Canonical segment count of the frozen reduction grid.
    pub fn segments(&self) -> usize {
        self.lock().placement.segments()
    }

    /// Alive worker channels right now.
    pub fn alive_workers(&self) -> usize {
        self.lock().workers.iter().filter(|w| w.conn.is_some()).count()
    }

    /// Per-worker status rows, in worker-index order.
    pub fn worker_rows(&self) -> Vec<WorkerRow> {
        let inner = self.lock();
        inner
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| WorkerRow {
                endpoint: w.endpoint.clone(),
                alive: w.conn.is_some(),
                pulls: w.pulls,
                in_flight: usize::from(inner.outstanding.is_pending(i)),
                restarts: w.restarts,
                p99_ms: w.p99_ms(),
            })
            .collect()
    }

    /// Ping every alive worker with `worker.health` under the health
    /// deadline; unresponsive workers are marked dead and their segments
    /// rebalanced. Returns the alive mask after the sweep.
    pub fn health_check(&self) -> Vec<bool> {
        let mut guard = self.lock();
        let inner = &mut *guard;
        let health = Duration::from_millis(self.cfg.health_timeout_ms.max(1));
        let request = Duration::from_millis(self.cfg.request_timeout_ms.max(1));
        let mut died = false;
        for w in inner.workers.iter_mut() {
            let Some(conn) = w.conn.as_mut() else { continue };
            conn.stream.set_read_timeout(Some(health)).ok();
            let ok = conn.rpc("worker.health", Value::from_pairs(Vec::new())).is_ok();
            conn.stream.set_read_timeout(Some(request)).ok();
            if !ok {
                w.conn = None;
                died = true;
            }
        }
        let alive: Vec<bool> = inner.workers.iter().map(|w| w.conn.is_some()).collect();
        if died && alive.iter().any(|&a| a) {
            let _ = inner.placement.assign(&alive);
        }
        alive
    }

    /// Test/bench hook: drop the channel to worker `w` as if its process
    /// vanished mid-run. The next block revives it (process still up) or
    /// re-dispatches its segments (process gone).
    pub fn drop_connection(&self, w: usize) {
        self.lock().workers[w].conn = None;
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panic while holding the lock (worker all-dead bail unwinding
        // through a caller) must not wedge every later query.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record a gather failure (first one wins) for the trait methods that
    /// have no error channel of their own.
    fn poison(&self, what: &str, e: &crate::Error) {
        let mut g = self.gather_failure.lock().unwrap_or_else(|p| p.into_inner());
        if g.is_none() {
            *g = Some(format!("{what}: {e:#}"));
        }
    }

    /// Take-and-clear the first failure recorded by a `pull_block` /
    /// `pull_matrix` since the last call. A `Some` means every sum the
    /// engine produced since then is suspect (zero-filled segments) and the
    /// enclosing run's answer must be discarded.
    pub fn take_failure(&self) -> Option<String> {
        self.gather_failure.lock().unwrap_or_else(|p| p.into_inner()).take()
    }

    /// Probe dead workers and rebalance if any rejoined. Rejoin repeats the
    /// full registration handshake: a *different* process listening on the
    /// old endpoint is only admitted if it serves the same digest.
    fn revive(&self, inner: &mut Inner) {
        let mut changed = false;
        for w in inner.workers.iter_mut() {
            if w.conn.is_some() {
                continue;
            }
            let Ok(mut conn) = Conn::open(&w.endpoint, &self.cfg) else { continue };
            match Self::handshake(&mut conn, &self.dataset, &self.register) {
                Ok(shape) if shape == (self.n, self.dim, self.metric, self.digest) => {
                    w.conn = Some(conn);
                    w.restarts += 1;
                    changed = true;
                }
                _ => {}
            }
        }
        if changed {
            let alive: Vec<bool> = inner.workers.iter().map(|w| w.conn.is_some()).collect();
            let _ = inner.placement.assign(&alive);
        }
    }

    fn pull_params(
        &self,
        arms: &[usize],
        refs: &[usize],
        groups: &[Vec<usize>],
        segs: &[usize],
        matrix: bool,
    ) -> Value {
        let ref_groups: Vec<Value> = segs
            .iter()
            .map(|&s| Value::Array(groups[s].iter().map(|&j| refs[j].into()).collect()))
            .collect();
        let mut pairs = vec![
            ("dataset", Value::from(self.dataset.as_str())),
            ("ref_groups", Value::Array(ref_groups)),
        ];
        // Round 0 pulls every arm: send the contiguous range instead of a
        // million-element id array.
        let contiguous = arms.len() > 1 && arms.windows(2).all(|w| w[1] == w[0] + 1);
        if contiguous {
            pairs.push((
                "arms_range",
                Value::Array(vec![arms[0].into(), (arms[arms.len() - 1] + 1).into()]),
            ));
        } else {
            pairs.push(("arms", Value::Array(arms.iter().map(|&a| a.into()).collect())));
        }
        if matrix {
            pairs.push(("matrix", true.into()));
        }
        Value::from_pairs(pairs)
    }

    /// Decode one worker response into the per-segment bit store; returns
    /// the worker's reported pull count. Any shape violation is treated by
    /// the caller as a worker failure (re-dispatch), never a partial fill:
    /// the response is validated group-by-group but only counted on full
    /// success, and a later re-dispatch overwrites whatever was written.
    fn absorb(
        &self,
        resp: &Value,
        arms: &[usize],
        groups: &[Vec<usize>],
        segs: &[usize],
        matrix: bool,
        bits: &mut [Vec<u64>],
    ) -> crate::Result<u64> {
        let key = if matrix { "dists" } else { "sums" };
        let rows = resp
            .get(key)
            .as_array()
            .with_context(|| format!("worker.pull response missing {key:?}"))?;
        crate::ensure!(
            rows.len() == segs.len(),
            "worker returned {} groups, expected {}",
            rows.len(),
            segs.len()
        );
        for (&s, row) in segs.iter().zip(rows) {
            let vals = row.as_array().context("worker.pull group is not an array")?;
            let want = if matrix { arms.len() * groups[s].len() } else { arms.len() };
            crate::ensure!(
                vals.len() == want,
                "worker group for segment {s} has {} values, expected {want}",
                vals.len()
            );
            let mut decoded = Vec::with_capacity(vals.len());
            for v in vals {
                decoded.push(v.as_u64().context("worker.pull: bad bit pattern")?);
            }
            bits[s] = decoded;
        }
        resp.get("pulls").as_u64().context("worker.pull response missing pulls")
    }

    /// The write-all / read-in-order / re-dispatch state machine shared by
    /// both pull paths.
    fn gather(&self, arms: &[usize], refs: &[usize], matrix: bool) -> crate::Result<Gathered> {
        let mut guard = self.lock();
        let inner = &mut *guard;
        self.revive(inner);

        let groups = inner.placement.split_idx(refs);
        let mut bits: Vec<Vec<u64>> = vec![Vec::new(); groups.len()];
        let mut plan: Vec<Vec<usize>> = vec![Vec::new(); inner.workers.len()];
        for (s, g) in groups.iter().enumerate() {
            if !g.is_empty() {
                plan[inner.placement.owner_of(s)].push(s);
            }
        }

        let mut failed: Vec<usize> = Vec::new();
        let mut sent_at: Vec<Option<Instant>> = vec![None; inner.workers.len()];

        // Write phase: one request per involved worker.
        for w in 0..inner.workers.len() {
            if plan[w].is_empty() {
                continue;
            }
            let params = self.pull_params(arms, refs, &groups, &plan[w], matrix);
            match inner.workers[w].conn.as_mut().map(|c| c.send("worker.pull", params)) {
                Some(Ok(id)) => {
                    inner.outstanding.issue(w, id, std::mem::take(&mut plan[w]))?;
                    sent_at[w] = Some(Instant::now());
                }
                _ => {
                    inner.workers[w].conn = None;
                    failed.append(&mut plan[w]);
                }
            }
        }

        // Read phase, in worker-index order.
        for w in 0..inner.workers.len() {
            if !inner.outstanding.is_pending(w) {
                continue;
            }
            let Some(pend) = inner.outstanding.take(w) else {
                // is_pending was checked above; a disagreeing take means the
                // entry vanished — treat the worker round as failed rather
                // than panicking mid-reduction.
                continue;
            };
            let absorbed = inner.workers[w].conn.as_mut().map(|c| c.recv(pend.id)).and_then(
                |resp| match resp {
                    Ok(v) => self.absorb(&v, arms, &groups, &pend.segs, matrix, &mut bits).ok(),
                    Err(_) => None,
                },
            );
            match absorbed {
                Some(pulls) => {
                    let worker = &mut inner.workers[w];
                    worker.pulls = worker.pulls.saturating_add(pulls);
                    if let Some(t0) = sent_at[w] {
                        worker.record_latency(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    self.remote_pulls.fetch_add(pulls, Ordering::Relaxed);
                }
                None => {
                    inner.workers[w].conn = None;
                    failed.extend(pend.segs);
                }
            }
        }

        // Re-dispatch: hand the dead workers' segments to the first
        // survivor; keep going down the line if survivors die too.
        while !failed.is_empty() {
            let Some(w) = (0..inner.workers.len()).find(|&i| inner.workers[i].conn.is_some())
            else {
                crate::bail!(
                    "all {} workers for dataset {:?} are dead; pull cannot complete",
                    inner.workers.len(),
                    self.dataset
                );
            };
            self.redispatches.fetch_add(1, Ordering::Relaxed);
            let segs = std::mem::take(&mut failed);
            let params = self.pull_params(arms, refs, &groups, &segs, matrix);
            let t0 = Instant::now();
            let absorbed = inner.workers[w]
                .conn
                .as_mut()
                .and_then(|c| c.rpc("worker.pull", params).ok())
                .and_then(|v| self.absorb(&v, arms, &groups, &segs, matrix, &mut bits).ok());
            match absorbed {
                Some(pulls) => {
                    let worker = &mut inner.workers[w];
                    worker.pulls = worker.pulls.saturating_add(pulls);
                    worker.record_latency(t0.elapsed().as_secs_f64() * 1e3);
                    self.remote_pulls.fetch_add(pulls, Ordering::Relaxed);
                }
                None => {
                    inner.workers[w].conn = None;
                    failed = segs;
                }
            }
        }

        // Rebalance ownership for subsequent blocks if anyone died.
        let alive: Vec<bool> = inner.workers.iter().map(|w| w.conn.is_some()).collect();
        if alive.iter().any(|&a| !a) && alive.iter().any(|&a| a) {
            let _ = inner.placement.assign(&alive);
        }
        Ok(Gathered { groups, bits })
    }
}

impl PullEngine for DistributedEngine {
    fn n(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn pull(&self, arm: usize, reference: usize) -> f32 {
        let mut out = [0f32];
        self.pull_matrix(&[arm], &[reference], &mut out);
        out[0]
    }

    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        let g = match self.gather(arms, refs, false) {
            Ok(g) => g,
            Err(e) => {
                // No error channel on the trait: zero-fill and poison the
                // engine so the enclosing request fails instead of the
                // whole event-loop worker panicking (lint rule R5).
                self.poison("pull_block", &e);
                out.fill(0.0);
                return;
            }
        };
        out.fill(0.0);
        // Canonical fold: ascending segment order, independent of which
        // worker produced each partial — this is the bitwise guarantee.
        for (s, group) in g.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let seg = &g.bits[s];
            for (o, &b) in out.iter_mut().zip(seg) {
                *o += f64::from_bits(b);
            }
        }
    }

    fn pull_matrix(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        assert_eq!(arms.len() * refs.len(), out.len());
        let g = match self.gather(arms, refs, true) {
            Ok(g) => g,
            Err(e) => {
                self.poison("pull_matrix", &e);
                out.fill(0.0);
                return;
            }
        };
        let rlen = refs.len();
        for (s, group) in g.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let seg = &g.bits[s];
            for k in 0..arms.len() {
                for (c, &j) in group.iter().enumerate() {
                    out[k * rlen + j] = f32::from_bits(seg[k * group.len() + c] as u32);
                }
            }
        }
    }

    fn reported_pulls(&self) -> Option<u64> {
        Some(self.remote_pulls())
    }
}

/// Coordinator-side session book: per-dataset distributed engines over a
/// fixed endpoint list (what `corrsh serve --coordinator` hangs off its
/// server state).
pub struct DistRuntime {
    endpoints: Vec<String>,
    cfg: DistConfig,
    engines: Mutex<HashMap<String, Arc<DistributedEngine>>>,
}

impl DistRuntime {
    pub fn new(endpoints: Vec<String>, cfg: DistConfig) -> Self {
        DistRuntime { endpoints, cfg, engines: Mutex::new(HashMap::new()) }
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    /// Forward a dataset registration to every worker and open the
    /// distributed session (replacing any previous session of that name).
    pub fn register(
        &self,
        dataset: &str,
        params: &Value,
        shard_rows: usize,
    ) -> crate::Result<Arc<DistributedEngine>> {
        let mut cfg = self.cfg.clone();
        cfg.shard_rows = shard_rows;
        let engine = Arc::new(DistributedEngine::connect(&self.endpoints, dataset, params, cfg)?);
        self.lock().insert(dataset.to_string(), Arc::clone(&engine));
        Ok(engine)
    }

    pub fn engine(&self, dataset: &str) -> Option<Arc<DistributedEngine>> {
        self.lock().get(dataset).cloned()
    }

    pub fn unregister(&self, dataset: &str) {
        self.lock().remove(dataset);
    }

    /// Total re-dispatch events across all sessions.
    pub fn redispatches(&self) -> u64 {
        self.sessions().iter().map(|e| e.redispatches()).sum()
    }

    /// Per-endpoint `metrics` rows, aggregated across sessions: pulls and
    /// restarts sum, p99 takes the worst session, alive if any session's
    /// channel is up. Empty-session coordinators report all-dead rows.
    pub fn worker_rows_value(&self) -> Value {
        let engines = self.sessions();
        let rows = self
            .endpoints
            .iter()
            .enumerate()
            .map(|(i, ep)| {
                let mut pulls = 0u64;
                let mut restarts = 0u64;
                let mut in_flight = 0usize;
                let mut alive = false;
                let mut p99: f64 = 0.0;
                for e in &engines {
                    let row = &e.worker_rows()[i];
                    pulls = pulls.saturating_add(row.pulls);
                    restarts += row.restarts;
                    in_flight += row.in_flight;
                    alive |= row.alive;
                    p99 = p99.max(row.p99_ms);
                }
                Value::from_pairs(vec![
                    ("endpoint", ep.as_str().into()),
                    ("alive", alive.into()),
                    ("pulls", pulls.into()),
                    ("in_flight", in_flight.into()),
                    ("restarts", restarts.into()),
                    ("p99_ms", p99.into()),
                ])
            })
            .collect();
        Value::Array(rows)
    }

    fn sessions(&self) -> Vec<Arc<DistributedEngine>> {
        self.lock().values().cloned().collect()
    }

    #[allow(clippy::type_complexity)]
    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Arc<DistributedEngine>>> {
        self.engines.lock().unwrap_or_else(|p| p.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    #[test]
    fn bits_value_roundtrips_every_pattern() {
        // The wire carries bit patterns, so the property is exact identity
        // — including NaN payloads, infinities and signed zeros, which a
        // float-in-JSON encoding would mangle or reject.
        for x in [0.0f64, -0.0, 1.5, -1.5e308, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = bits_value(x.to_bits());
            assert_eq!(v.as_u64(), Some(x.to_bits()));
        }
        testing::check(
            "bits-value-roundtrip",
            testing::default_cases(),
            |rng| rng.next_u64(),
            |&bits, _| {
                let v = bits_value(bits);
                // the encoding must survive an actual serialize/parse cycle
                let wire = json::to_string(&Value::Array(vec![v]));
                let back = json::parse(&wire).map_err(|e| e.to_string())?;
                match back.idx(0).as_u64() {
                    Some(b) if b == bits => Ok(()),
                    other => Err(format!("{bits:#x} came back as {other:?}")),
                }
            },
        );
    }

    #[test]
    fn f32_bits_always_ride_as_numbers() {
        for x in [0.0f32, -0.0, 3.25, f32::NAN, f32::INFINITY] {
            match bits_value(x.to_bits() as u64) {
                Value::Num(_) => {}
                v => panic!("f32 bits must encode as a JSON number, got {v:?}"),
            }
        }
    }
}
