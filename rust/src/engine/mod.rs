//! Pull engines: the "arm pull" abstraction of the bandit reduction.
//!
//! A pull is one distance computation `d(x_i, x_j)` — the unit the paper
//! counts on its x-axes. The bandit algorithms only see [`PullEngine`]; the
//! concrete engines are:
//!
//! * [`NativeEngine`] — vectorized CPU sweeps over the dataset, thread-
//!   parallel over arm tiles via the persistent worker pool: dense blocks
//!   run on the GEMM-style tiled kernel layer ([`kernel`] — packed ref
//!   tiles, register micro-tiles, norm-trick L2/cosine with a cancellation
//!   guard), sparse blocks on the densified-reference CSR fast paths. The
//!   wall-clock workhorse and the correctness oracle for the PJRT path.
//!   Construction is split: [`PreparedEngine`] holds the O(n·d)
//!   precomputations (norms, squared norms, row-reductions) as a shareable
//!   session, and [`NativeEngine::from_prepared`] wraps one for free.
//! * [`EngineCache`] — keyed `(dataset, metric) → Arc<PreparedEngine>`
//!   cache so repeated queries (the server's steady state) prepare once.
//! * `PjrtEngine` (feature `pjrt`) — executes the AOT-compiled L1/L2
//!   artifacts through the PJRT runtime, batching (arm×ref) tiles into
//!   bucket-shaped jobs (see `runtime/` and `coordinator/planner`).
//! * [`DistributedEngine`] — fans blocks out to N worker processes over
//!   the service wire protocol and folds the f64 partials in canonical
//!   segment order, so results are bitwise-identical at any worker count
//!   and survive worker death via re-dispatch (DESIGN.md §15).
//! * [`CountingEngine`] — decorator adding atomic pull accounting.
//!
//! The micro-kernels under both native hot paths live in [`simd`]:
//! explicit AVX2/NEON kernels behind one-time runtime dispatch
//! (`CORRSH_KERNEL` override), with the scalar reference kept
//! bitwise-authoritative (DESIGN.md §14).

pub mod cache;
pub mod distributed;
pub mod kernel;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;

pub use cache::EngineCache;
pub use distributed::{DistConfig, DistRuntime, DistributedEngine, WorkerRow};
pub use native::{NativeEngine, PreparedEngine};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use crate::distance::Metric;
use crate::metrics::Counter;

/// Batched access to distances against a common dataset.
///
/// `pull_block` is the hot path: `out[k] = Σ_{j ∈ refs} d(x_arms[k], x_j)`.
/// Engines may compute the pulls in any order but must include every
/// (arm, ref) pair exactly once — the correlation property of Algorithm 1
/// comes from the *caller* passing the same `refs` for all arms.
///
/// Precision policy (DESIGN.md §9): individual distances are `f32` (the
/// kernel/artifact dtype), but block **sums** are produced in `f64` — with
/// `t_r` up to `n` references per arm, `t · d(x_i, x_j)` overflows f32's
/// 24-bit mantissa long before the paper's dataset scales, which silently
/// biased the round estimator.
///
/// Deliberately NOT `Sync`: the PJRT engine wraps a single-threaded PJRT
/// client handle (the `xla` crate's client is `Rc`-based). Parallel trial
/// runners bound on `PullEngine + Sync` generically and use the native
/// engine, which is `Sync`.
pub trait PullEngine {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    fn metric(&self) -> Metric;

    /// One distance computation.
    fn pull(&self, arm: usize, reference: usize) -> f32;

    /// Sum of distances from each arm to all of `refs`, accumulated in f64.
    /// Default: scalar loop.
    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        for (k, &a) in arms.iter().enumerate() {
            out[k] = refs.iter().map(|&r| self.pull(a, r) as f64).sum();
        }
    }

    /// Full distance rows (for the stats engine / Figs 3-4-6):
    /// `out[k*refs.len() + j] = d(arms[k], refs[j])`.
    fn pull_matrix(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        assert_eq!(arms.len() * refs.len(), out.len());
        for (k, &a) in arms.iter().enumerate() {
            for (j, &r) in refs.iter().enumerate() {
                out[k * refs.len() + j] = self.pull(a, r);
            }
        }
    }

    /// Pulls this engine's *remote* backends have reported executing, when
    /// the engine is fed by report frames ([`DistributedEngine`]); `None`
    /// for engines that compute locally. The bandit loop uses the delta
    /// across a block to charge the budget ledger with what workers
    /// actually did rather than what the schedule assumed.
    fn reported_pulls(&self) -> Option<u64> {
        None
    }
}

/// Decorator counting every pull that flows through.
pub struct CountingEngine<E: PullEngine> {
    inner: E,
    counter: Counter,
}

impl<E: PullEngine> CountingEngine<E> {
    pub fn new(inner: E) -> Self {
        CountingEngine { inner, counter: Counter::new() }
    }

    pub fn pulls(&self) -> u64 {
        self.counter.get()
    }

    pub fn reset(&self) {
        self.counter.reset();
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: PullEngine> PullEngine for CountingEngine<E> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn metric(&self) -> Metric {
        self.inner.metric()
    }

    fn pull(&self, arm: usize, reference: usize) -> f32 {
        self.counter.add(1);
        self.inner.pull(arm, reference)
    }

    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        self.counter.add((arms.len() * refs.len()) as u64);
        self.inner.pull_block(arms, refs, out);
    }

    fn pull_matrix(&self, arms: &[usize], refs: &[usize], out: &mut [f32]) {
        self.counter.add((arms.len() * refs.len()) as u64);
        self.inner.pull_matrix(arms, refs, out);
    }

    fn reported_pulls(&self) -> Option<u64> {
        self.inner.reported_pulls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};

    #[test]
    fn counting_wrapper_counts_everything() {
        let data =
            gaussian::generate(&SynthConfig { n: 30, dim: 8, seed: 0, ..Default::default() });
        let e = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        assert_eq!(e.pulls(), 0);
        let _ = e.pull(0, 1);
        assert_eq!(e.pulls(), 1);
        let mut out = vec![0f64; 4];
        e.pull_block(&[0, 1, 2, 3], &[5, 6, 7], &mut out);
        assert_eq!(e.pulls(), 1 + 12);
        let mut m = vec![0f32; 6];
        e.pull_matrix(&[0, 1], &[3, 4, 5], &mut m);
        assert_eq!(e.pulls(), 1 + 12 + 6);
        e.reset();
        assert_eq!(e.pulls(), 0);
    }

    #[test]
    fn default_block_matches_pulls() {
        struct Toy;
        impl PullEngine for Toy {
            fn n(&self) -> usize {
                10
            }
            fn dim(&self) -> usize {
                1
            }
            fn metric(&self) -> Metric {
                Metric::L1
            }
            fn pull(&self, a: usize, r: usize) -> f32 {
                (a * 100 + r) as f32
            }
        }
        let mut out = vec![0f64; 2];
        Toy.pull_block(&[1, 2], &[3, 4], &mut out);
        assert_eq!(out, vec![103.0 + 104.0, 203.0 + 204.0]);
        let mut m = vec![0f32; 4];
        Toy.pull_matrix(&[1, 2], &[3, 4], &mut m);
        assert_eq!(m, vec![103.0, 104.0, 203.0, 204.0]);
    }
}
