//! Tiled dense block kernels — the GEMM-style hot path behind
//! `NativeEngine::pull_block` / `pull_matrix` on dense data (DESIGN.md §11).
//!
//! The correlated round shape scores *every* surviving arm against the same
//! reference set, which makes the dense pull workload a tall-skinny
//! arm × ref product. The seed path evaluated it one (arm, ref) pair at a
//! time — every pair re-streamed both rows and did one FMA per two loads.
//! This layer restructures it the way a register-blocked GEMM would:
//!
//! * **Packing.** The reference rows are repacked k-major — one cache
//!   block at a time, into a per-worker scratch — as tiles of
//!   [`REF_LANES`] rows (`packed[k·8 + lane] = ref_lane[k]`), so the
//!   micro-kernel's innermost loop reads one contiguous 8-wide f32 vector
//!   per feature index — the layout LLVM auto-vectorizes reliably. Short
//!   tiles are zero-padded; padded lanes are computed and discarded
//!   (their chains never touch a real lane's accumulator).
//! * **Register micro-tile.** [`ARM_TILE`] arms × [`REF_LANES`] refs per
//!   micro-kernel call: 4 broadcast loads + 1 packed vector load feed 32
//!   multiply-accumulates, versus 2 loads per 1 FMA on the per-pair path.
//!   Arm remainders dispatch to `MR ∈ {1,2,3}` instantiations of the same
//!   const-generic kernel, so a pair's arithmetic — and therefore its
//!   result, bitwise — does not depend on which tile it landed in.
//! * **Cache blocking.** Packed ref tiles are visited in blocks sized to
//!   keep ~[`BLOCK_BUDGET_F32`] floats resident (L2-sized), with the whole
//!   arm chunk swept per block so each packed tile loaded from memory is
//!   reused across every arm tile.
//! * **Norm trick.** L2 and cosine share one dot-product micro-kernel via
//!   `d²(a,b) = ‖a‖² + ‖b‖² − 2⟨a,b⟩`, with squared norms precomputed once
//!   per session (`PreparedEngine`, f64). Cancellation guard: lane products
//!   accumulate in f32 but fold into f64 every [`SEG_LEN`] features, and a
//!   pair whose d² lands below [`L2_CANCEL_REL`] of `‖a‖² + ‖b‖²` (near
//!   duplicates, where the subtraction would eat the mantissa) falls back
//!   to the direct `Σ(a−b)²` kernel; the surviving fast path clamps at
//!   `max(0, ·)` before the sqrt. NaN inputs take the fallback too (every
//!   comparison fails), so poisoned rows still propagate NaN instead of
//!   being laundered to 0 by the clamp.
//!
//! Precision policy (DESIGN.md §9) is preserved: individual distances stay
//! f32, block sums accumulate in f64 in reference order, so results are
//! bitwise identical across thread counts and ref-block sizes.

use crate::coordinator::planner::shard_aligned_chunk;
use crate::data::{DenseData, ShardedData};
use crate::distance::{dense, Metric};
use crate::engine::simd::{self, Variant};
use crate::util::threads;

// The micro-kernels themselves (scalar reference + AVX2/NEON mirrors,
// runtime-dispatched) live in `engine::simd`; this layer owns packing,
// blocking and the metric combine step. Re-exported so geometry constants
// keep their historical `kernel::` paths.
pub use crate::engine::simd::{REF_LANES, SEG_LEN};

/// Arms per register micro-tile (broadcast operands).
pub const ARM_TILE: usize = 4;
/// Packed floats kept resident per ref block (256 KiB — L2-sized).
const BLOCK_BUDGET_F32: usize = 1 << 16;
/// Norm-trick cancellation guard: fall back to the direct kernel when
/// `d² ≤ L2_CANCEL_REL · (‖a‖² + ‖b‖²)`. Above the cutoff the f32 lane
/// rounding in the dot is ≤ ~1e-6 of the norms' scale, keeping the fast
/// path within 1e-5 relative of the scalar reference; below it the rows
/// are near-duplicates and `Σ(a−b)²` is both cheap (rare) and exact.
const L2_CANCEL_REL: f64 = 0.1;

/// Row source for the tile kernels: a resident dense matrix or an on-disk
/// shard store. Rows come out bitwise identical either way — resident and
/// mapped shards lend zero-copy slices, the pinned shard reader gathers
/// into per-worker scratch — so tile results (and therefore bandit
/// decisions) do not depend on where the bytes live (DESIGN.md §12).
#[derive(Clone, Copy)]
pub enum DenseRows<'a> {
    Resident(&'a DenseData),
    Sharded(&'a ShardedData),
}

impl<'a> From<&'a DenseData> for DenseRows<'a> {
    fn from(d: &'a DenseData) -> Self {
        DenseRows::Resident(d)
    }
}

impl<'a> From<&'a ShardedData> for DenseRows<'a> {
    fn from(sd: &'a ShardedData) -> Self {
        assert!(!sd.is_sparse(), "dense tile kernels over a sparse shard set");
        DenseRows::Sharded(sd)
    }
}

impl<'a> DenseRows<'a> {
    #[inline]
    pub fn dim(&self) -> usize {
        match self {
            DenseRows::Resident(d) => d.dim,
            DenseRows::Sharded(sd) => sd.dim(),
        }
    }

    /// Rows per shard (0 = resident — no boundaries to align to).
    fn shard_rows(&self) -> usize {
        match self {
            DenseRows::Resident(_) => 0,
            DenseRows::Sharded(sd) => sd.rows_per_shard(),
        }
    }

    /// Zero-copy row borrow; `None` means the caller must gather.
    #[inline]
    fn try_row(&self, i: usize) -> Option<&'a [f32]> {
        match self {
            DenseRows::Resident(d) => Some(d.row(i)),
            DenseRows::Sharded(sd) => sd.try_dense_row(i),
        }
    }

    #[inline]
    fn copy_row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            DenseRows::Resident(d) => out.copy_from_slice(d.row(i)),
            DenseRows::Sharded(sd) => sd.with_dense_row(i, |row| out.copy_from_slice(row)),
        }
    }
}

/// Repack ref tiles `[t0, t1)` k-major into `scratch`:
/// `scratch[(t−t0)·8·dim + k·8 + lane] = row(refs[t·8 + lane])[k]`,
/// zero-padding missing lanes. Packing one cache block at a time keeps the
/// transient footprint at ~[`BLOCK_BUDGET_F32`] floats per worker — a
/// full-universe ref set (the exact sweeps pass `refs = 0..n`) would
/// otherwise duplicate the whole dataset per call. `row_tmp` is the gather
/// scratch for row sources that cannot lend zero-copy slices.
fn pack_block(
    rows: &DenseRows<'_>,
    refs: &[usize],
    t0: usize,
    t1: usize,
    scratch: &mut Vec<f32>,
    row_tmp: &mut Vec<f32>,
) {
    let dim = rows.dim();
    scratch.clear();
    scratch.resize((t1 - t0) * REF_LANES * dim, 0.0);
    let block_refs = &refs[t0 * REF_LANES..(t1 * REF_LANES).min(refs.len())];
    for (j, &r) in block_refs.iter().enumerate() {
        let tile = &mut scratch[(j / REF_LANES) * REF_LANES * dim..];
        let lane = j % REF_LANES;
        let row = match rows.try_row(r) {
            Some(row) => row,
            None => {
                row_tmp.resize(dim, 0.0);
                rows.copy_row_into(r, row_tmp);
                &row_tmp[..]
            }
        };
        for (k, &v) in row.iter().enumerate() {
            tile[k * REF_LANES + lane] = v;
        }
    }
}

/// One dense-tile kernel session: the row source plus the per-metric
/// precomputations the combine step reads (`PreparedEngine` owns them).
pub struct DenseTileCtx<'a> {
    rows: DenseRows<'a>,
    metric: Metric,
    /// Euclidean row norms (cosine).
    norms: Option<&'a [f32]>,
    /// f64 squared row norms (L2 norm trick).
    sq_norms: Option<&'a [f64]>,
    /// Packed ref tiles visited per cache block (tests override this to
    /// pin determinism across blockings; see [`Self::with_block_tiles`]).
    block_tiles: usize,
    /// Micro-kernel variant the sweeps dispatch to. Defaults to the
    /// process-wide [`simd::active`] choice; differential tests and the
    /// SIMD benches pin it via [`Self::with_variant`]. Safe to force
    /// anywhere: the dispatch layer re-verifies the CPU feature and
    /// degrades to scalar rather than trusting the value.
    variant: Variant,
}

impl<'a> DenseTileCtx<'a> {
    /// `norms` is required for [`Metric::Cosine`], `sq_norms` for
    /// [`Metric::L2`] (both precomputed once in `PreparedEngine`).
    pub fn new(
        rows: impl Into<DenseRows<'a>>,
        metric: Metric,
        norms: Option<&'a [f32]>,
        sq_norms: Option<&'a [f64]>,
    ) -> Self {
        let rows = rows.into();
        assert!(
            metric != Metric::Cosine || norms.is_some(),
            "cosine tile kernel needs precomputed norms"
        );
        assert!(
            metric != Metric::L2 || sq_norms.is_some(),
            "l2 tile kernel needs precomputed squared norms"
        );
        let block_tiles = (BLOCK_BUDGET_F32 / (REF_LANES * rows.dim().max(1))).clamp(1, 64);
        DenseTileCtx { rows, metric, norms, sq_norms, block_tiles, variant: simd::active() }
    }

    /// Override the ref cache-block size (in packed tiles). Results are
    /// bitwise independent of this — pinned by the determinism tests.
    pub fn with_block_tiles(mut self, tiles: usize) -> Self {
        self.block_tiles = tiles.max(1);
        self
    }

    /// Pin the micro-kernel variant instead of the process-wide dispatch
    /// choice. Results are bitwise independent of this too — that is the
    /// SIMD contract, pinned by the differential property tests.
    pub fn with_variant(mut self, variant: Variant) -> Self {
        self.variant = variant;
        self
    }

    /// Distances of `arm_ids` (1..=[`ARM_TILE`]) against one packed ref
    /// tile, into `out[i][lane]` for the `tile_refs.len()` valid lanes.
    /// `arm_rows` are the arm row slices (zero-copy or gathered by
    /// `sweep_chunk` — bitwise identical either way).
    fn tile_distances<const MR: usize>(
        &self,
        arm_ids: &[usize],
        arm_rows: &[&[f32]],
        tile_refs: &[usize],
        packed: &[f32],
        ref_tmp: &mut Vec<f32>,
        out: &mut [[f32; REF_LANES]; ARM_TILE],
    ) {
        let rows: [&[f32]; MR] = std::array::from_fn(|i| arm_rows[i]);
        match self.metric {
            Metric::L1 => {
                let sums = simd::l1_tile::<MR>(self.variant, &rows, packed);
                for i in 0..MR {
                    for (o, &s) in out[i][..tile_refs.len()].iter_mut().zip(&sums[i]) {
                        *o = s as f32;
                    }
                }
            }
            Metric::L2 => {
                let dots = simd::dot_tile::<MR>(self.variant, &rows, packed);
                let sq = self.sq_norms.expect("checked in new()");
                for i in 0..MR {
                    let sa = sq[arm_ids[i]];
                    for (l, &r) in tile_refs.iter().enumerate() {
                        let scale = sa + sq[r];
                        let d2 = scale - 2.0 * dots[i][l];
                        // NaN d2 fails the comparison and lands in the
                        // fallback, which propagates it — the clamp only
                        // ever sees finite positives.
                        out[i][l] = if d2 > L2_CANCEL_REL * scale {
                            d2.max(0.0).sqrt() as f32
                        } else {
                            let b = match self.rows.try_row(r) {
                                Some(s) => s,
                                None => {
                                    ref_tmp.resize(rows[i].len(), 0.0);
                                    self.rows.copy_row_into(r, ref_tmp);
                                    &ref_tmp[..]
                                }
                            };
                            dense::l2sq_dense(rows[i], b).sqrt()
                        };
                    }
                }
            }
            Metric::Cosine => {
                let dots = simd::dot_tile::<MR>(self.variant, &rows, packed);
                let norms = self.norms.expect("checked in new()");
                for i in 0..MR {
                    let na = norms[arm_ids[i]];
                    for (l, &r) in tile_refs.iter().enumerate() {
                        let denom = na * norms[r];
                        // Zero rows → distance 1, same convention as
                        // `cosine_dense`; NaN norms fail the guard and
                        // propagate.
                        out[i][l] = if denom <= 1e-24 {
                            1.0
                        } else {
                            (1.0 - dots[i][l] / denom as f64) as f32
                        };
                    }
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn tile_distances_dyn(
        &self,
        arm_ids: &[usize],
        arm_rows: &[&[f32]],
        tile_refs: &[usize],
        packed: &[f32],
        ref_tmp: &mut Vec<f32>,
        out: &mut [[f32; REF_LANES]; ARM_TILE],
    ) {
        match arm_ids.len() {
            1 => self.tile_distances::<1>(arm_ids, arm_rows, tile_refs, packed, ref_tmp, out),
            2 => self.tile_distances::<2>(arm_ids, arm_rows, tile_refs, packed, ref_tmp, out),
            3 => self.tile_distances::<3>(arm_ids, arm_rows, tile_refs, packed, ref_tmp, out),
            4 => self.tile_distances::<4>(arm_ids, arm_rows, tile_refs, packed, ref_tmp, out),
            n => unreachable!("arm micro-tile of {n} > ARM_TILE"),
        }
    }

    /// The determinism-critical tile sweep for one ARM_TILE-aligned arm
    /// chunk: ref cache blocks outer, arm tiles mid, ref tiles inner —
    /// everything ascending — calling
    /// `emit(arm_offset_in_chunk, mr, ref_tile, lanes, dists)` per
    /// micro-tile. Both public entry points drive this one loop, so the
    /// blocking/alignment logic that tile membership (and therefore
    /// bitwise reproducibility) depends on cannot diverge between them.
    fn sweep_chunk(
        &self,
        chunk_arms: &[usize],
        refs: &[usize],
        mut emit: impl FnMut(usize, usize, usize, usize, &[[f32; REF_LANES]; ARM_TILE]),
    ) {
        let dim = self.rows.dim();
        let n_tiles = refs.len().div_ceil(REF_LANES);
        let mut dists = [[0f32; REF_LANES]; ARM_TILE];
        let mut packed = Vec::new();
        let mut row_tmp = Vec::new();
        let mut ref_tmp = Vec::new();
        // Gather target for arm rows the store can't lend zero-copy (the
        // pinned shard reader): one micro-tile's worth, refilled per tile —
        // tiny next to the 4×`lanes`×dim distance work it feeds.
        let mut arm_scratch = vec![0f32; ARM_TILE * dim];
        for t0 in (0..n_tiles).step_by(self.block_tiles) {
            let t1 = (t0 + self.block_tiles).min(n_tiles);
            pack_block(&self.rows, refs, t0, t1, &mut packed, &mut row_tmp);
            for a0 in (0..chunk_arms.len()).step_by(ARM_TILE) {
                let mr = (chunk_arms.len() - a0).min(ARM_TILE);
                let arm_ids = &chunk_arms[a0..a0 + mr];
                let mut arm_rows: [&[f32]; ARM_TILE] = [&[]; ARM_TILE];
                let direct = arm_ids.iter().all(|&a| self.rows.try_row(a).is_some());
                if direct {
                    for (k, &a) in arm_ids.iter().enumerate() {
                        arm_rows[k] = self.rows.try_row(a).expect("checked direct");
                    }
                } else {
                    for (k, &a) in arm_ids.iter().enumerate() {
                        self.rows.copy_row_into(a, &mut arm_scratch[k * dim..(k + 1) * dim]);
                    }
                    for (k, slot) in arm_rows.iter_mut().enumerate().take(mr) {
                        *slot = &arm_scratch[k * dim..(k + 1) * dim];
                    }
                }
                for t in t0..t1 {
                    let lanes = (refs.len() - t * REF_LANES).min(REF_LANES);
                    let tile_refs = &refs[t * REF_LANES..t * REF_LANES + lanes];
                    let tile = &packed[(t - t0) * REF_LANES * dim..][..REF_LANES * dim];
                    self.tile_distances_dyn(
                        arm_ids,
                        &arm_rows[..mr],
                        tile_refs,
                        tile,
                        &mut ref_tmp,
                        &mut dists,
                    );
                    emit(a0, mr, t, lanes, &dists);
                }
            }
        }
    }

    /// `out[k] = Σ_{j ∈ refs} d(arms[k], refs[j])`, accumulated in f64 in
    /// reference order (bitwise thread/blocking-independent).
    pub fn block_sums(&self, arms: &[usize], refs: &[usize], threads: usize, out: &mut [f64]) {
        assert_eq!(arms.len(), out.len());
        out.fill(0.0);
        if arms.is_empty() || refs.is_empty() {
            return;
        }
        // Chunks are ARM_TILE-aligned so an arm's tile membership — hence
        // its micro-kernel instantiation — is identical at any thread
        // count; sharded sources additionally land on shard boundaries
        // (shard alignment never breaks tile alignment, so results stay
        // bitwise identical to the resident split).
        let chunk =
            shard_aligned_chunk(arms.len(), threads.max(1) * 4, ARM_TILE, self.rows.shard_rows());
        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            let chunk_arms = &arms[start..start + slot.len()];
            self.sweep_chunk(chunk_arms, refs, |a0, mr, _t, lanes, dists| {
                for (i, row) in dists.iter().enumerate().take(mr) {
                    let mut tile_sum = 0f64;
                    for &d in &row[..lanes] {
                        tile_sum += d as f64;
                    }
                    slot[a0 + i] += tile_sum;
                }
            });
        });
    }

    /// `out[k·refs.len() + j] = d(arms[k], refs[j])` (row-major).
    pub fn matrix(&self, arms: &[usize], refs: &[usize], threads: usize, out: &mut [f32]) {
        let m = refs.len();
        assert_eq!(arms.len() * m, out.len());
        if out.is_empty() {
            return;
        }
        let chunk =
            shard_aligned_chunk(arms.len(), threads.max(1) * 4, ARM_TILE, self.rows.shard_rows())
                * m;
        threads::parallel_chunks_mut(out, chunk, threads, |start, slot| {
            debug_assert_eq!(start % m, 0);
            let arm0 = start / m;
            let chunk_arms = &arms[arm0..arm0 + slot.len() / m];
            self.sweep_chunk(chunk_arms, refs, |a0, mr, t, lanes, dists| {
                for (i, row) in dists.iter().enumerate().take(mr) {
                    let dst = &mut slot[(a0 + i) * m + t * REF_LANES..][..lanes];
                    dst.copy_from_slice(&row[..lanes]);
                }
            });
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing;

    /// f64 scalar reference: the ground truth every tiled kernel is held
    /// to (f32 inputs, f64 arithmetic throughout).
    fn naive_f64(metric: Metric, a: &[f32], b: &[f32]) -> f64 {
        match metric {
            Metric::L1 => a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).abs()).sum(),
            Metric::L2 => a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = (x - y) as f64;
                    d * d
                })
                .sum::<f64>()
                .sqrt(),
            Metric::Cosine => {
                let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
                let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                let nb: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
                if na * nb <= 1e-24 {
                    1.0
                } else {
                    1.0 - dot / (na * nb)
                }
            }
        }
    }

    fn random_data(rng: &mut Rng, n: usize, dim: usize, scale: f64) -> DenseData {
        let raw: Vec<f32> = (0..n * dim).map(|_| (rng.gaussian() * scale) as f32).collect();
        DenseData::new(n, dim, raw)
    }

    fn ctx_over<'a>(
        data: &'a DenseData,
        metric: Metric,
        norms: &'a [f32],
        sq: &'a [f64],
    ) -> DenseTileCtx<'a> {
        DenseTileCtx::new(data, metric, Some(norms), Some(sq))
    }

    fn prep(data: &DenseData) -> (Vec<f32>, Vec<f64>) {
        let norms: Vec<f32> = (0..data.n).map(|i| dense::norm(data.row(i))).collect();
        let sq: Vec<f64> = (0..data.n).map(|i| dense::sqnorm_f64(data.row(i))).collect();
        (norms, sq)
    }

    /// Every metric × odd dims (segment tails) × arm/ref counts off the
    /// tile grid, block_sums AND matrix, against the f64 scalar reference.
    #[test]
    fn tiled_kernels_match_scalar_reference() {
        testing::check(
            "dense-tile-parity",
            testing::default_cases(),
            |rng| {
                let dim = [1, 2, 3, 5, 8, 17, 63, 64, 65, 129, 300][rng.below(11)];
                let n_arms = 1 + rng.below(13);
                let n_refs = 1 + rng.below(19);
                let threads = 1 + rng.below(4);
                (dim, n_arms, n_refs, threads)
            },
            |&(dim, n_arms, n_refs, threads), rng| {
                let n = 40;
                let data = random_data(rng, n, dim, 1.0);
                let (norms, sq) = prep(&data);
                let arms: Vec<usize> = (0..n_arms).map(|_| rng.below(n)).collect();
                let refs: Vec<usize> = (0..n_refs).map(|_| rng.below(n)).collect();
                for metric in Metric::ALL {
                    let ctx = ctx_over(&data, metric, &norms, &sq);
                    let mut sums = vec![0f64; n_arms];
                    ctx.block_sums(&arms, &refs, threads, &mut sums);
                    let mut mat = vec![0f32; n_arms * n_refs];
                    ctx.matrix(&arms, &refs, threads, &mut mat);
                    for (k, &a) in arms.iter().enumerate() {
                        let mut want_sum = 0f64;
                        for (j, &r) in refs.iter().enumerate() {
                            let want = naive_f64(metric, data.row(a), data.row(r));
                            want_sum += want;
                            let got = mat[k * n_refs + j] as f64;
                            if (got - want).abs() > 1e-5 * want.abs().max(1.0) {
                                return Err(format!(
                                    "{metric} d={dim} matrix ({a},{r}): {got} vs {want}"
                                ));
                            }
                        }
                        if (sums[k] - want_sum).abs() > 1e-5 * want_sum.abs().max(1.0) {
                            return Err(format!(
                                "{metric} d={dim} block arm {a}: {} vs {want_sum}",
                                sums[k]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Near-duplicate rows at large magnitude: the norm-trick subtraction
    /// cancels catastrophically, so these pairs must take the direct-kernel
    /// fallback — never a NaN or a negative distance, and bitwise equal to
    /// the scalar f32 kernel the fallback delegates to.
    #[test]
    fn near_duplicates_hit_the_fallback_not_nan() {
        let dim = 96;
        let mut rng = Rng::seeded(77);
        let base: Vec<f32> = (0..dim).map(|_| (rng.gaussian() * 1e6) as f32).collect();
        let mut raw = base.clone();
        raw.extend(base.iter().map(|v| v + 1e-1)); // ~1e-7 relative offset
        raw.extend(base.iter().cloned()); // exact duplicate
        raw.extend((0..dim).map(|_| (rng.gaussian() * 1e6) as f32)); // far row
        let data = DenseData::new(4, dim, raw);
        let (norms, sq) = prep(&data);
        for metric in [Metric::L2, Metric::Cosine, Metric::L1] {
            let ctx = ctx_over(&data, metric, &norms, &sq);
            let arms = [0usize, 1, 2, 3];
            let mut mat = vec![0f32; 16];
            ctx.matrix(&arms, &arms, 1, &mut mat);
            for (p, &d) in mat.iter().enumerate() {
                assert!(!d.is_nan(), "{metric} pair {p} produced NaN");
                // cosine may round to a hair below zero on duplicates (same
                // convention as the scalar kernels); L1/L2 must not.
                let floor = if metric == Metric::Cosine { -1e-5 } else { 0.0 };
                assert!(d >= floor, "{metric} pair {p} produced negative distance {d}");
            }
            if metric == Metric::L2 {
                // diagonal: exact zero through the fallback
                for i in 0..4 {
                    assert_eq!(mat[i * 4 + i], 0.0, "self-distance row {i}");
                }
                // the near-duplicate pair delegates to the direct kernel —
                // bitwise equality, not just tolerance
                assert_eq!(mat[1], dense::l2_dense(data.row(0), data.row(1)));
                assert_eq!(mat[2], dense::l2_dense(data.row(0), data.row(2)));
            }
        }
    }

    /// Results are bitwise identical across thread counts, ref cache-block
    /// sizes, and arm-list splits (tile-membership independence).
    #[test]
    fn bitwise_deterministic_across_tilings_and_threads() {
        let mut rng = Rng::seeded(5);
        let data = random_data(&mut rng, 60, 131, 1.0);
        let (norms, sq) = prep(&data);
        let arms: Vec<usize> = (0..57).collect(); // 57 % 4 != 0
        let refs: Vec<usize> = (0..29).collect(); // 29 % 8 != 0
        for metric in Metric::ALL {
            let mut base_sums = vec![0f64; arms.len()];
            let mut base_mat = vec![0f32; arms.len() * refs.len()];
            {
                let ctx = ctx_over(&data, metric, &norms, &sq);
                ctx.block_sums(&arms, &refs, 1, &mut base_sums);
                ctx.matrix(&arms, &refs, 1, &mut base_mat);
            }
            for block_tiles in [1usize, 2, 1024] {
                for threads in [1usize, 3, 8] {
                    let ctx = ctx_over(&data, metric, &norms, &sq).with_block_tiles(block_tiles);
                    let mut sums = vec![0f64; arms.len()];
                    ctx.block_sums(&arms, &refs, threads, &mut sums);
                    assert_eq!(
                        sums, base_sums,
                        "{metric}: block_sums diverged at block_tiles={block_tiles} \
                         threads={threads}"
                    );
                    let mut mat = vec![0f32; arms.len() * refs.len()];
                    ctx.matrix(&arms, &refs, threads, &mut mat);
                    assert_eq!(
                        mat, base_mat,
                        "{metric}: matrix diverged at block_tiles={block_tiles} \
                         threads={threads}"
                    );
                }
            }
            // Dropping the last arm changes every tile's membership near
            // the tail; shared arms must not move by a single bit.
            let ctx = ctx_over(&data, metric, &norms, &sq);
            let mut shorter = vec![0f64; arms.len() - 1];
            ctx.block_sums(&arms[..arms.len() - 1], &refs, 4, &mut shorter);
            assert_eq!(&base_sums[..shorter.len()], &shorter[..], "{metric}: subset diverged");
        }
    }

    #[test]
    fn zero_rows_cosine_is_one_through_tiles() {
        let mut raw = vec![0f32; 8 * 10];
        for v in raw.iter_mut().skip(10) {
            *v = 1.0;
        }
        let data = DenseData::new(8, 10, raw);
        let (norms, sq) = prep(&data);
        let ctx = ctx_over(&data, Metric::Cosine, &norms, &sq);
        let arms: Vec<usize> = (0..8).collect();
        let mut mat = vec![0f32; 64];
        ctx.matrix(&arms, &arms, 1, &mut mat);
        for j in 0..8 {
            assert_eq!(mat[j], 1.0, "zero row vs row {j}");
        }
    }

    #[test]
    fn nan_rows_propagate_through_tiles() {
        let mut raw = vec![0.5f32; 12 * 6];
        raw[3 * 6 + 2] = f32::NAN;
        let data = DenseData::new(12, 6, raw);
        let (norms, sq) = prep(&data);
        for metric in Metric::ALL {
            let ctx = ctx_over(&data, metric, &norms, &sq);
            let arms: Vec<usize> = (0..12).collect();
            let mut sums = vec![0f64; 12];
            ctx.block_sums(&arms, &arms, 1, &mut sums);
            assert!(sums.iter().all(|s| s.is_nan()), "{metric}: poisoned ref must taint sums");
            let mut mat = vec![0f32; 12 * 12];
            ctx.matrix(&arms, &arms, 1, &mut mat);
            for k in 0..12 {
                assert!(mat[k * 12 + 3].is_nan(), "{metric}: ({k},3) must be NaN");
                assert!(mat[3 * 12 + k].is_nan(), "{metric}: (3,{k}) must be NaN");
            }
        }
    }

    /// The tile layer is storage-blind: a shard-backed row source (pinned
    /// reader, evicting cache) must produce bitwise the same sums and
    /// matrices as the resident matrix it was written from.
    #[test]
    fn sharded_rows_bitwise_equal_resident() {
        use crate::data::store::{write_sharded, ShardedData, StoreOptions};
        use crate::data::Data;
        let mut rng = Rng::seeded(31);
        let data = random_data(&mut rng, 50, 67, 1.0);
        let (norms, sq) = prep(&data);
        let dir = std::env::temp_dir().join("corrsh-kernel-tests").join("tile-parity");
        let _ = std::fs::remove_dir_all(&dir);
        let manifest = write_sharded(&Data::Dense(data.clone()), &dir, 12).unwrap();
        // a cache holding ~2 blocks of 4 rows each forces mid-sweep churn
        let opts = StoreOptions {
            cache_bytes: 2 * 4 * 67 * 4,
            block_bytes: 4 * 67 * 4,
            force_pinned: true,
        };
        let pinned = ShardedData::open_with(&manifest, &opts).unwrap();
        let default = ShardedData::open(&manifest).unwrap();
        let arms: Vec<usize> = (0..45).collect(); // 45 % 4 != 0
        let refs: Vec<usize> = (5..42).collect(); // 37 % 8 != 0
        for metric in Metric::ALL {
            let ctx = ctx_over(&data, metric, &norms, &sq);
            let mut base_sums = vec![0f64; arms.len()];
            let mut base_mat = vec![0f32; arms.len() * refs.len()];
            ctx.block_sums(&arms, &refs, 3, &mut base_sums);
            ctx.matrix(&arms, &refs, 3, &mut base_mat);
            for sd in [&pinned, &default] {
                let ctx = DenseTileCtx::new(sd, metric, Some(&norms[..]), Some(&sq[..]));
                for threads in [1usize, 4] {
                    let mut sums = vec![0f64; arms.len()];
                    ctx.block_sums(&arms, &refs, threads, &mut sums);
                    assert_eq!(sums, base_sums, "{metric}: sharded sums diverged");
                    let mut mat = vec![0f32; arms.len() * refs.len()];
                    ctx.matrix(&arms, &refs, threads, &mut mat);
                    assert_eq!(mat, base_mat, "{metric}: sharded matrix diverged");
                }
            }
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let data = random_data(&mut Rng::seeded(1), 5, 7, 1.0);
        let (norms, sq) = prep(&data);
        let ctx = ctx_over(&data, Metric::L1, &norms, &sq);
        let mut sums = vec![7f64; 3];
        ctx.block_sums(&[0, 1, 2], &[], 4, &mut sums);
        assert_eq!(sums, vec![0.0; 3], "no refs → zero sums");
        let mut none: Vec<f64> = vec![];
        ctx.block_sums(&[], &[0], 4, &mut none);
        let mut mat: Vec<f32> = vec![];
        ctx.matrix(&[], &[0, 1], 4, &mut mat);
    }
}
