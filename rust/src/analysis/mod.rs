//! In-tree static analysis: the `corrsh lint` invariant analyzer.
//!
//! The paper's reproducibility claims survive on a handful of repo-wide
//! invariants (total_cmp-only comparators, audited `unsafe`, panic-free
//! event loop, waivered float equality — the full table is DESIGN.md §16).
//! They used to be policed by grep/awk one-liners in CI that could not see
//! strings, comments, or `#[cfg(test)]` blocks; this module replaces them
//! with a token-level analyzer built on a small Rust lexer
//! ([`lexer`]) and a rule engine ([`rules`]), zero dependencies.
//!
//! Entry points:
//! - [`lint_root`] walks `rust/src`, `rust/tests`, `rust/benches`, and
//!   `examples` under a repo root and returns a [`Report`];
//! - [`check_source`] lints one (path, source) pair — what the fixture
//!   corpus in `rust/tests/lint_corpus.rs` drives directly;
//! - the CLI front-end is `corrsh lint [--ci] [--root DIR] [--out FILE]`.

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Value;

pub use rules::{check_source, Finding, RuleInfo, RULES};

/// Bumped when rule semantics change, so CI artifacts and the server
/// metrics row can tell which analyzer produced a report.
pub const LINT_VERSION: u64 = 2;

/// Directories under the repo root that `lint_root` scans for `.rs` files.
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Outcome of linting a tree: every finding plus scan statistics.
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form for `--ci` and the uploaded artifact.
    pub fn to_json(&self) -> Value {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Value::from_pairs(vec![
                    ("rule", Value::Str(f.rule.to_string())),
                    ("file", Value::Str(f.file.clone())),
                    ("line", Value::Num(f.line as f64)),
                    ("message", Value::Str(f.message.clone())),
                ])
            })
            .collect();
        Value::from_pairs(vec![
            ("version", Value::Num(LINT_VERSION as f64)),
            ("rules", Value::Num(RULES.len() as f64)),
            ("files_scanned", Value::Num(self.files_scanned as f64)),
            ("findings", Value::Array(findings)),
            ("ok", Value::Bool(self.ok())),
        ])
    }

    /// Human-readable form: one `file:line: [Rn] message` row per finding.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        s.push_str(&format!(
            "lint v{LINT_VERSION}: {} file(s), {} rule(s), {} finding(s)\n",
            self.files_scanned,
            RULES.len(),
            self.findings.len()
        ));
        s
    }
}

/// Lint every `.rs` file under [`SCAN_ROOTS`] relative to `root`.
/// Findings are ordered by (path, line) so reports are deterministic.
pub fn lint_root(root: &Path) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in SCAN_ROOTS {
        let d = root.join(dir);
        if d.is_dir() {
            collect_rs(&d, &mut files)?;
        }
    }
    files.sort();

    let mut findings = Vec::new();
    for path in &files {
        let rel = rel_path(root, path);
        let src = fs::read_to_string(path)
            .with_context(|| format!("lint: read {}", path.display()))?;
        findings.extend(check_source(&rel, &src));
    }
    findings.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(Report { findings, files_scanned: files.len() })
}

/// Repo-relative path with forward slashes (rule scopes are defined on
/// this form, so reports are identical across platforms).
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        fs::read_dir(dir).with_context(|| format!("lint: read_dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("lint: entry under {}", dir.display()))?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let rep = Report {
            findings: vec![Finding {
                rule: "R1",
                file: "rust/src/x.rs".into(),
                line: 3,
                message: "m".into(),
            }],
            files_scanned: 2,
        };
        let v = rep.to_json();
        assert_eq!(v.get("version").as_u64(), Some(LINT_VERSION));
        assert_eq!(v.get("rules").as_usize(), Some(RULES.len()));
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("findings").idx(0).get("rule").as_str(), Some("R1"));
        let text = rep.render_text();
        assert!(text.contains("rust/src/x.rs:3: [R1] m"));
    }

    #[test]
    fn rule_table_is_eight_rules() {
        assert_eq!(RULES.len(), 8);
        let ids: Vec<_> = RULES.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec!["R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"]);
    }
}
