//! Token-level Rust lexer for the in-tree lint analyzer.
//!
//! This is not a full Rust lexer — it is exactly the subset the invariant
//! rules in [`super::rules`] need to avoid the false-positive classes that
//! killed the old grep/awk CI gates:
//!
//! - line comments and (nested) block comments are real tokens, so a rule
//!   can anchor on `// SAFETY:` text and never fire on `partial_cmp`
//!   mentioned in prose;
//! - string literals (`"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `c"…"`)
//!   and char/byte-char literals are skipped as single tokens, so `unsafe`
//!   inside a fixture string is invisible to the rules;
//! - lifetimes (`'a`) are disambiguated from char literals (`'a'`) so a
//!   quote never desynchronizes the scan;
//! - numeric literals carry an `is_float` flag (fraction, exponent, or
//!   `f32`/`f64` suffix) so the float-comparison rule can match
//!   literal-adjacent `==`/`!=` without type information.
//!
//! The lexer is lossless enough for the rules (every non-whitespace byte
//! belongs to exactly one token) and never panics on malformed input: an
//! unterminated literal simply extends to end-of-file.

/// Token classes the rule engine distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`unsafe`, `partial_cmp`, `thread`, …).
    Ident,
    /// Numeric literal; `is_float` on the token records float-ness.
    Num,
    /// String literal of any flavor, including the quotes and raw hashes.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`) — *not* a char literal.
    Lifetime,
    /// Punctuation; common two-char operators (`==`, `!=`, `::`, …) are
    /// single tokens, everything else is one byte per token.
    Punct,
    /// `// …` up to (not including) the newline.
    LineComment,
    /// `/* … */` with nesting; may span lines.
    BlockComment,
}

/// One lexed token. `text` borrows from the source; `line` is the 1-based
/// line of the token's first byte.
#[derive(Clone, Copy, Debug)]
pub struct Tok<'s> {
    pub kind: Kind,
    pub text: &'s str,
    pub line: u32,
    /// For [`Kind::Num`]: literal has a fractional part, exponent, or an
    /// `f32`/`f64` suffix. Always `false` for other kinds.
    pub is_float: bool,
}

impl Tok<'_> {
    /// Last line the token touches (block comments span lines).
    pub fn end_line(&self) -> u32 {
        self.line + self.text.bytes().filter(|&b| b == b'\n').count() as u32
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Two-character operators lexed as single punct tokens. Order matters only
/// in that every entry is checked before falling back to one byte.
const TWO_CHAR_OPS: &[&str] = &[
    "==", "!=", "<=", ">=", "::", "->", "=>", "..", "&&", "||",
];

/// Lex `src` into tokens, comments included. Never fails: unterminated
/// literals run to end-of-input.
pub fn lex(src: &str) -> Vec<Tok<'_>> {
    Lexer { src, b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'s> {
    src: &'s str,
    b: &'s [u8],
    i: usize,
    line: u32,
    out: Vec<Tok<'s>>,
}

impl<'s> Lexer<'s> {
    fn run(mut self) -> Vec<Tok<'s>> {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                c if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(self.i, 0, false),
                b'\'' => self.char_or_lifetime(),
                c if is_ident_start(c) => self.ident_or_prefixed_literal(),
                c if c.is_ascii_digit() => self.number(),
                _ => self.punct(),
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn push(&mut self, kind: Kind, start: usize, start_line: u32, is_float: bool) {
        self.out.push(Tok { kind, text: &self.src[start..self.i], line: start_line, is_float });
    }

    fn line_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        self.push(Kind::LineComment, start, line, false);
    }

    fn block_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match self.b[self.i] {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.i += 2;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.i += 2;
                }
                _ => self.i += 1,
            }
        }
        self.push(Kind::BlockComment, start, line, false);
    }

    /// Plain or raw string starting at the current `"`; `hashes` is the raw
    /// delimiter count (`r#"…"#` → 1); `raw` disables backslash escapes
    /// (true for `r"…"` even with zero hashes). `start` points at the
    /// literal's first byte (the prefix if any).
    fn string(&mut self, start: usize, hashes: usize, raw: bool) {
        let line = self.line;
        debug_assert_eq!(self.b[self.i], b'"');
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b'\\' if !raw => self.i = (self.i + 2).min(self.b.len()),
                b'"' => {
                    // A raw string closes only on `"` followed by enough `#`.
                    let closed = (1..=hashes).all(|k| self.peek(k) == Some(b'#'));
                    self.i += 1;
                    if closed {
                        self.i += hashes;
                        self.push(Kind::Str, start, line, false);
                        return;
                    }
                }
                _ => self.i += 1,
            }
        }
        self.push(Kind::Str, start, line, false); // unterminated: to EOF
    }

    fn char_or_lifetime(&mut self) {
        let (start, line) = (self.i, self.line);
        match self.peek(1) {
            Some(b'\\') => {
                // Escaped char literal: skip `'\`, the escape payload, and
                // scan to the closing quote ( covers \n \' \u{…} \x7f ).
                self.i += 2;
                if self.i < self.b.len() {
                    self.i += 1; // escape selector is never the terminator
                }
                while self.i < self.b.len() && self.b[self.i] != b'\'' && self.b[self.i] != b'\n' {
                    self.i += 1;
                }
                if self.peek(0) == Some(b'\'') {
                    self.i += 1;
                }
                self.push(Kind::Char, start, line, false);
            }
            Some(c) => {
                // One UTF-8 char then a quote → char literal ('a', '∂');
                // otherwise an identifier start means a lifetime ('a, 'static).
                let ch_len = self.src[self.i + 1..]
                    .chars()
                    .next()
                    .map(|ch| ch.len_utf8())
                    .unwrap_or(1);
                if self.b.get(self.i + 1 + ch_len) == Some(&b'\'') {
                    self.i += 2 + ch_len;
                    self.push(Kind::Char, start, line, false);
                } else if is_ident_start(c) {
                    self.i += 2;
                    while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                        self.i += 1;
                    }
                    self.push(Kind::Lifetime, start, line, false);
                } else {
                    self.i += 1;
                    self.push(Kind::Punct, start, line, false);
                }
            }
            None => {
                self.i += 1;
                self.push(Kind::Punct, start, line, false);
            }
        }
    }

    fn ident_or_prefixed_literal(&mut self) {
        let (start, line) = (self.i, self.line);
        while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
            self.i += 1;
        }
        let word = &self.src[start..self.i];

        // Literal prefixes: b"…" c"…" r"…" br"…" cr"…" r#"…"# b'…' and the
        // raw-identifier escape r#ident.
        let raw = matches!(word, "r" | "br" | "cr");
        let stringy = raw || matches!(word, "b" | "c");
        match self.peek(0) {
            Some(b'"') if stringy => {
                self.string(start, 0, raw);
                return;
            }
            Some(b'#') if raw => {
                let mut hashes = 0usize;
                while self.peek(hashes) == Some(b'#') {
                    hashes += 1;
                }
                if self.peek(hashes) == Some(b'"') {
                    self.i += hashes;
                    self.string(start, hashes, true);
                    return;
                }
                if word == "r" && self.peek(1).is_some_and(is_ident_start) {
                    // raw identifier r#match — lex as one ident token
                    self.i += 1;
                    while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                        self.i += 1;
                    }
                    self.push(Kind::Ident, start, line, false);
                    return;
                }
            }
            Some(b'\'') if word == "b" => {
                // Byte-char literal b'x' / b'\n' — reuse the char scanner,
                // then widen the token to include the `b` prefix.
                self.char_or_lifetime();
                let src = self.src;
                let end = self.i;
                if let Some(last) = self.out.last_mut() {
                    last.kind = Kind::Char;
                    last.text = &src[start..end];
                }
                return;
            }
            _ => {}
        }
        self.push(Kind::Ident, start, line, false);
    }

    fn number(&mut self) {
        let (start, line) = (self.i, self.line);
        // A number right after `.` is a tuple index (t.0, t.0.1) — never a
        // float, and its own `.` must not be eaten as a fraction.
        let after_dot = self
            .out
            .last()
            .is_some_and(|t| t.kind == Kind::Punct && t.text == ".");
        let mut is_float = false;

        if self.peek(0) == Some(b'0')
            && matches!(self.peek(1), Some(b'x') | Some(b'o') | Some(b'b'))
        {
            self.i += 2;
            while self
                .peek(0)
                .is_some_and(|c| c.is_ascii_hexdigit() || c == b'_')
            {
                self.i += 1;
            }
        } else {
            while self.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
                self.i += 1;
            }
            if !after_dot
                && self.peek(0) == Some(b'.')
                && self.peek(1) != Some(b'.')
                && !self.peek(1).is_some_and(is_ident_start)
            {
                is_float = true;
                self.i += 1;
                while self.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
                    self.i += 1;
                }
            }
            if matches!(self.peek(0), Some(b'e') | Some(b'E')) {
                let sign = matches!(self.peek(1), Some(b'+') | Some(b'-'));
                let digit_at = if sign { 2 } else { 1 };
                if self.peek(digit_at).is_some_and(|c| c.is_ascii_digit()) {
                    is_float = true;
                    self.i += 1 + usize::from(sign);
                    while self.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
                        self.i += 1;
                    }
                }
            }
        }
        // Type suffix (1u64, 2.5f32, 1f64) — part of the literal token.
        let suffix_start = self.i;
        while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
            self.i += 1;
        }
        if matches!(&self.src[suffix_start..self.i], "f32" | "f64") {
            is_float = true;
        }
        self.push(Kind::Num, start, line, is_float);
    }

    fn punct(&mut self) {
        let (start, line) = (self.i, self.line);
        let two = self
            .src
            .get(self.i..self.i + 2)
            .filter(|p| TWO_CHAR_OPS.contains(p));
        self.i += if two.is_some() { 2 } else { 1 };
        self.push(Kind::Punct, start, line, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).iter().map(|t| (t.kind, t.text.to_string())).collect()
    }

    #[test]
    fn comments_and_strings_are_single_tokens() {
        let toks = kinds("a // partial_cmp here\n/* unsafe /* nested */ */ \"x.unwrap()\"");
        assert_eq!(
            toks,
            vec![
                (Kind::Ident, "a".into()),
                (Kind::LineComment, "// partial_cmp here".into()),
                (Kind::BlockComment, "/* unsafe /* nested */ */".into()),
                (Kind::Str, "\"x.unwrap()\"".into()),
            ]
        );
    }

    #[test]
    fn raw_strings_and_prefixes() {
        let toks = kinds(r####"r#"has "quote" and unsafe"# br"bytes" b"b" c"c" r#match"####);
        assert_eq!(toks[0].0, Kind::Str);
        assert!(toks[0].1.contains("unsafe"));
        assert_eq!(toks[1].0, Kind::Str);
        assert_eq!(toks[2].0, Kind::Str);
        assert_eq!(toks[3].0, Kind::Str);
        assert_eq!(toks[4], (Kind::Ident, "r#match".into()));
    }

    #[test]
    fn chars_vs_lifetimes() {
        let toks = kinds("'a' 'x 'static b'\\n' '\\u{1F600}' fn f<'b>()");
        assert_eq!(toks[0].0, Kind::Char);
        assert_eq!(toks[1], (Kind::Lifetime, "'x".into()));
        assert_eq!(toks[2], (Kind::Lifetime, "'static".into()));
        assert_eq!(toks[3].0, Kind::Char);
        assert_eq!(toks[4].0, Kind::Char);
        let lt = toks.iter().filter(|t| t.0 == Kind::Lifetime).count();
        assert_eq!(lt, 3, "'b in the generics is a lifetime");
    }

    #[test]
    fn float_detection() {
        let f = |src: &str| {
            lex(src)
                .iter()
                .filter(|t| t.kind == Kind::Num)
                .map(|t| t.is_float)
                .collect::<Vec<_>>()
        };
        assert_eq!(f("1.0 2 3e5 4f32 5f64 0.25e-3"), vec![true, false, true, true, true, true]);
        assert_eq!(f("0x1E 1..2 t.0.1 7u64"), vec![false, false, false, false, false, false]);
        assert_eq!(f("1.max(2)"), vec![false, false], "method call on int, not a float");
    }

    #[test]
    fn two_char_ops_coalesce() {
        let toks = kinds("a == b != c :: d . e");
        let puncts: Vec<_> =
            toks.iter().filter(|t| t.0 == Kind::Punct).map(|t| t.1.clone()).collect();
        assert_eq!(puncts, vec!["==", "!=", "::", "."]);
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let src = "a\n/* two\nlines */\nb \"s1\ns2\"\nc";
        let toks = lex(src);
        let find = |txt: &str| toks.iter().find(|t| t.text == txt).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 4);
        assert_eq!(find("c"), 6);
        let block = toks.iter().find(|t| t.kind == Kind::BlockComment).unwrap();
        assert_eq!((block.line, block.end_line()), (2, 3));
    }
}
