//! Invariant rules R1–R8 over the token stream from [`super::lexer`].
//!
//! Every rule is a token-pattern check, so string literals, comments, and
//! doc text can never fire a rule (the grep-gate failure mode), and
//! `#[cfg(test)]` / `#[test]` item bodies are tracked by brace matching so
//! test-only code can be exempted where a rule says so.
//!
//! Scope conventions (paths are repo-relative, forward slashes):
//! - allowlists name exact files;
//! - R5's production scope is `rust/src/server/**` plus
//!   `rust/src/engine/distributed.rs`;
//! - everything else applies to every scanned `.rs` file.

use super::lexer::{lex, Kind, Tok};

/// One rule violation. `file` is the repo-relative path the caller handed
/// to [`check_source`]; `line` is 1-based.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Static descriptor for one rule, surfaced in `lint --ci` JSON and the
/// server metrics row.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The rule table. `RULES.len()` is the rule count reported everywhere.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "R1",
        summary: "no partial_cmp anywhere (total_cmp keeps NaN ordering deterministic)",
    },
    RuleInfo {
        id: "R2",
        summary: "unsafe only in the audited allowlist, each use within 4 lines of a // SAFETY: comment",
    },
    RuleInfo {
        id: "R3",
        summary: "raw syscalls / asm! only in data/store/reader.rs and server/net.rs",
    },
    RuleInfo {
        id: "R4",
        summary: "thread::spawn only in util/pool.rs and util/threads.rs",
    },
    RuleInfo {
        id: "R5",
        summary: "no unwrap/expect/panic! in non-test server/ and engine/distributed.rs code",
    },
    RuleInfo {
        id: "R6",
        summary: "no float ==/!= without an inline // lint: float-eq-ok(reason) waiver",
    },
    RuleInfo {
        id: "R7",
        summary: "std::process::exit only in main.rs",
    },
    RuleInfo {
        id: "R8",
        summary: "no unchecked + on pull-ledger counters in non-test code; \
                  use saturating_add or a // lint: pull-add-ok(reason) waiver",
    },
];

/// Files audited to contain `unsafe` (R2). Growing this list is a review
/// decision, not a code change that happens to compile — see DESIGN.md §16.
const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/engine/simd.rs",
    "rust/src/data/store/reader.rs",
    "rust/src/server/net.rs",
    "rust/src/util/pool.rs",
    "rust/src/runtime/mod.rs",
];

/// Files allowed to issue raw syscalls / `asm!` (R3).
const SYSCALL_ALLOWLIST: &[&str] = &["rust/src/data/store/reader.rs", "rust/src/server/net.rs"];

/// Files allowed to spawn OS threads (R4); everything else routes through
/// the pool or `util::threads::spawn`.
const SPAWN_ALLOWLIST: &[&str] = &["rust/src/util/pool.rs", "rust/src/util/threads.rs"];

/// Files allowed to call `std::process::exit` (R7).
const EXIT_ALLOWLIST: &[&str] = &["rust/src/main.rs"];

/// Max distance (in lines) from the anchor of a `// SAFETY:` comment run to
/// the `unsafe` token it covers. A run of consecutive line comments anchors
/// at its *last* line, so a four-line justification directly above an
/// `unsafe` block (or separated from it by attributes) still passes.
const SAFETY_WINDOW: u32 = 4;

fn in_r5_scope(path: &str) -> bool {
    path.starts_with("rust/src/server/") || path == "rust/src/engine/distributed.rs"
}

/// A maximal run of adjacent comment lines, anchored at `last`.
struct CommentRun {
    last: u32,
    safety: bool,
}

/// Run all rules over one file's source. `path` must be repo-relative with
/// forward slashes (e.g. `rust/src/server/ops.rs`); it selects which
/// allowlists and scopes apply.
pub fn check_source(path: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);

    // Comment geometry: SAFETY anchor runs and float-eq waiver lines.
    let mut runs: Vec<CommentRun> = Vec::new();
    let mut waiver_lines: Vec<u32> = Vec::new();
    let mut pull_waiver_lines: Vec<u32> = Vec::new();
    for t in &toks {
        if !matches!(t.kind, Kind::LineComment | Kind::BlockComment) {
            continue;
        }
        let safety = t.text.contains("SAFETY:");
        if t.text.contains("lint: float-eq-ok(") {
            waiver_lines.push(t.end_line());
        }
        if t.text.contains("lint: pull-add-ok(") {
            pull_waiver_lines.push(t.end_line());
        }
        match runs.last_mut() {
            Some(run) if t.line <= run.last + 1 => {
                run.last = t.end_line();
                run.safety |= safety;
            }
            _ => runs.push(CommentRun { last: t.end_line(), safety }),
        }
    }
    let safety_near = |line: u32| {
        runs.iter()
            .any(|r| r.safety && r.last <= line && line - r.last <= SAFETY_WINDOW)
    };
    let waived = |line: u32| waiver_lines.iter().any(|&w| w == line || w + 1 == line);
    let pull_waived = |line: u32| pull_waiver_lines.iter().any(|&w| w == line || w + 1 == line);

    // Code view: comments stripped, with per-token test-scope flags.
    let code: Vec<&Tok> = toks
        .iter()
        .filter(|t| !matches!(t.kind, Kind::LineComment | Kind::BlockComment))
        .collect();
    let in_test = test_flags(&code);

    let mut out = Vec::new();
    let mut fire = |rule: &'static str, line: u32, message: String| {
        out.push(Finding { rule, file: path.to_string(), line, message });
    };
    let ident =
        |k: usize, s: &str| code.get(k).is_some_and(|t| t.kind == Kind::Ident && t.text == s);
    let punct =
        |k: usize, s: &str| code.get(k).is_some_and(|t| t.kind == Kind::Punct && t.text == s);
    let float = |k: usize| code.get(k).is_some_and(|t| t.kind == Kind::Num && t.is_float);

    for k in 0..code.len() {
        let t = code[k];
        if t.kind == Kind::Ident {
            match t.text {
                // R1 — everywhere, tests included: a NaN-unsound comparator
                // in a test still launders the bug class the rule exists for.
                "partial_cmp" => fire(
                    "R1",
                    t.line,
                    "partial_cmp is banned; use total_cmp (NaN-last) comparators".into(),
                ),
                "unsafe" => {
                    if !UNSAFE_ALLOWLIST.contains(&path) {
                        fire(
                            "R2",
                            t.line,
                            format!("unsafe outside the audited allowlist ({path})"),
                        );
                    } else if !safety_near(t.line) {
                        fire(
                            "R2",
                            t.line,
                            format!(
                                "unsafe without a // SAFETY: comment anchored within \
                                 {SAFETY_WINDOW} lines"
                            ),
                        );
                    }
                }
                "asm" if punct(k + 1, "!") && !SYSCALL_ALLOWLIST.contains(&path) => fire(
                    "R3",
                    t.line,
                    "asm! outside the raw-syscall shims (reader.rs / net.rs)".into(),
                ),
                s if s.starts_with("syscall") && !SYSCALL_ALLOWLIST.contains(&path) => fire(
                    "R3",
                    t.line,
                    "raw syscall helper outside reader.rs / net.rs".into(),
                ),
                "thread"
                    if punct(k + 1, "::")
                        && ident(k + 2, "spawn")
                        && !SPAWN_ALLOWLIST.contains(&path) =>
                {
                    fire(
                        "R4",
                        t.line,
                        "thread::spawn outside util/pool.rs|util/threads.rs; \
                         use util::threads::spawn or the worker pool"
                            .into(),
                    )
                }
                "panic" if punct(k + 1, "!") && in_r5_scope(path) && !in_test[k] => fire(
                    "R5",
                    t.line,
                    "panic! in event-loop code; return util::error via bail!".into(),
                ),
                "unwrap" | "expect"
                    if punct(k.wrapping_sub(1), ".")
                        && punct(k + 1, "(")
                        && in_r5_scope(path)
                        && !in_test[k] =>
                {
                    fire(
                        "R5",
                        t.line,
                        format!(
                            ".{}() in event-loop code; use util::error::Context \
                             (or a poison-recovering lock)",
                            t.text
                        ),
                    )
                }
                "process"
                    if punct(k + 1, "::")
                        && ident(k + 2, "exit")
                        && !EXIT_ALLOWLIST.contains(&path) =>
                {
                    fire(
                        "R7",
                        t.line,
                        "process::exit outside main.rs hides shutdown paths".into(),
                    )
                }
                _ => {}
            }
        } else if t.kind == Kind::Punct && (t.text == "==" || t.text == "!=") {
            // R6 — float-literal-adjacent comparison. `x == -1.0` keeps the
            // unary minus between the operator and the literal.
            let rhs_float = float(k + 1) || (punct(k + 1, "-") && float(k + 2));
            if (float(k.wrapping_sub(1)) || rhs_float) && !waived(t.line) {
                fire(
                    "R6",
                    t.line,
                    format!(
                        "float `{}` comparison without a // lint: float-eq-ok(reason) waiver",
                        t.text
                    ),
                );
            }
        } else if t.kind == Kind::Punct && t.text == "+" && !in_test[k] {
            // R8 — pull-ledger arithmetic must saturate: a wrapped u64 pull
            // counter silently corrupts every budget/accounting invariant
            // downstream. The lexer splits `+=` into `+` `=`, so one anchor
            // covers both plain addition and compound assignment. An
            // operand is pull-like when an ident containing "pulls" sits
            // immediately left of the `+`, or anywhere in the (possibly
            // `self.`/path-qualified) operand chain to its right.
            let lhs_hit = code
                .get(k.wrapping_sub(1))
                .is_some_and(|p| p.kind == Kind::Ident && p.text.contains("pulls"));
            let mut j = k + 1;
            if punct(j, "=") {
                j += 1; // compound assign: inspect the addend
            }
            let mut rhs_hit = false;
            while let Some(p) = code.get(j) {
                match p.kind {
                    Kind::Ident => {
                        rhs_hit |= p.text.contains("pulls");
                        j += 1;
                    }
                    Kind::Punct if p.text == "." || p.text == "::" => j += 1,
                    _ => break,
                }
            }
            if (lhs_hit || rhs_hit) && !pull_waived(t.line) {
                fire(
                    "R8",
                    t.line,
                    "unchecked `+` on a pull counter; use saturating_add \
                     (or waive: // lint: pull-add-ok(reason))"
                        .into(),
                );
            }
        }
    }
    out
}

/// Per-token flag: inside a `#[cfg(test)]` or `#[test]` item body.
///
/// Brace-matching walk: a test attribute arms the *next* `{` (the item
/// body); a `;` before any `{` disarms it (out-of-line `mod t;`, statics).
/// Spans nest and close when their opening depth is popped.
fn test_flags(code: &[&Tok]) -> Vec<bool> {
    let mut flags = vec![false; code.len()];
    let mut depth: u32 = 0;
    let mut test_open: Vec<u32> = Vec::new();
    let mut armed = false;
    let mut k = 0;
    while k < code.len() {
        let t = code[k];
        if t.kind == Kind::Punct && t.text == "#" && code.get(k + 1).is_some_and(|n| n.text == "[")
        {
            // Whole attribute, bracket-matched; inspect its inner tokens.
            let start = k + 2;
            let mut j = start;
            let mut b = 1u32;
            while j < code.len() && b > 0 {
                match code[j].text {
                    "[" => b += 1,
                    "]" => b -= 1,
                    _ => {}
                }
                j += 1;
            }
            let inner: Vec<&str> =
                code[start..j.saturating_sub(1)].iter().map(|t| t.text).collect();
            if inner == ["test"] || inner == ["cfg", "(", "test", ")"] {
                armed = true;
            }
            let inside = !test_open.is_empty();
            for f in &mut flags[k..j] {
                *f = inside;
            }
            k = j;
            continue;
        }
        if t.kind == Kind::Punct {
            match t.text {
                "{" => {
                    depth += 1;
                    if armed {
                        test_open.push(depth);
                        armed = false;
                    }
                }
                "}" => {
                    if test_open.last() == Some(&depth) {
                        test_open.pop();
                    }
                    depth = depth.saturating_sub(1);
                }
                ";" if test_open.is_empty() => armed = false,
                _ => {}
            }
        }
        flags[k] = !test_open.is_empty();
        k += 1;
    }
    flags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(path: &str, src: &str) -> Vec<&'static str> {
        check_source(path, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn r5_respects_cfg_test_spans() {
        let src = "
            fn run(x: Option<u32>) -> u32 { x.expect(\"boom\") }
            #[cfg(test)]
            mod tests {
                #[test]
                fn ok() { Some(1).unwrap(); panic!(\"fine in tests\"); }
            }
        ";
        let fired = rules_fired("rust/src/server/ops.rs", src);
        assert_eq!(fired, vec!["R5"], "only the non-test expect fires");
    }

    #[test]
    fn r2_safety_run_anchor() {
        // Four-line justification + an attribute line still lands within
        // the window because the run anchors at its last line.
        let src = "
            // SAFETY: line one of a long justification,
            // line two,
            // line three,
            // line four.
            #[allow(clippy::useless_transmute)]
            unsafe { transmute(x) }
        ";
        assert!(rules_fired("rust/src/util/pool.rs", src).is_empty());
        let bare = "fn f() { unsafe { g() } }";
        assert_eq!(rules_fired("rust/src/util/pool.rs", bare), vec!["R2"]);
        assert_eq!(rules_fired("rust/src/server/ops.rs", bare), vec!["R2"]);
    }

    #[test]
    fn r6_waiver_same_line_or_above() {
        let hit = "fn f(x: f64) -> bool { x == 0.0 }";
        assert_eq!(rules_fired("rust/src/util/json.rs", hit), vec!["R6"]);
        let same = "fn f(x: f64) -> bool { x == 0.0 } // lint: float-eq-ok(test)";
        assert!(rules_fired("rust/src/util/json.rs", same).is_empty());
        let above = "// lint: float-eq-ok(test)\nfn f(x: f64) -> bool { -1.0 != x }";
        assert!(rules_fired("rust/src/util/json.rs", above).is_empty());
        let int = "fn f(x: u32) -> bool { x == 0 && x != 3 }";
        assert!(rules_fired("rust/src/util/json.rs", int).is_empty());
    }

    #[test]
    fn r8_pull_counter_addition() {
        // `+=` lexes as `+` `=`: both compound assignment and plain
        // addition on pull-like idents fire, on either operand side.
        let lhs = "fn f(mut pulls: u64, t: u64) { pulls += t; }";
        assert_eq!(rules_fired("rust/src/bandits/x.rs", lhs), vec!["R8"]);
        let rhs = "fn f(mut spent: u64, pulls: u64) { spent += pulls; }";
        assert_eq!(rules_fired("rust/src/coordinator/x.rs", rhs), vec!["R8"]);
        let qualified = "fn f(w: &mut W, row: R) { w.pulls += row.pulls; }";
        assert_eq!(rules_fired("rust/src/engine/x.rs", qualified), vec!["R8"]);
        let plain = "fn f(a: u64, o: O) -> u64 { a + o.reported_pulls }";
        assert_eq!(rules_fired("rust/src/kmedoids/x.rs", plain), vec!["R8"]);

        // saturating_add is the sanctioned form; unrelated counters and
        // waived lines stay silent; test scope is exempt.
        let ok = "fn f(mut pulls: u64, t: u64) { pulls = pulls.saturating_add(t); }";
        assert!(rules_fired("rust/src/bandits/x.rs", ok).is_empty());
        let other = "fn f(mut hits: u64) { hits += 1; }";
        assert!(rules_fired("rust/src/bandits/x.rs", other).is_empty());
        let waived = "fn f(mut pulls: u64) { pulls += 1; } // lint: pull-add-ok(test fixture)";
        assert!(rules_fired("rust/src/bandits/x.rs", waived).is_empty());
        let test_scope = "
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() { let mut pulls = 0u64; pulls += 3; }
            }
        ";
        assert!(rules_fired("rust/src/bandits/x.rs", test_scope).is_empty());
    }

    #[test]
    fn paths_select_allowlists() {
        let spawn = "fn f() { std::thread::spawn(|| ()); }";
        assert_eq!(rules_fired("rust/benches/server.rs", spawn), vec!["R4"]);
        assert!(rules_fired("rust/src/util/threads.rs", spawn).is_empty());
        let exit = "fn f() { std::process::exit(1); }";
        assert_eq!(rules_fired("rust/src/server/ops.rs", exit), vec!["R7"]);
        assert!(rules_fired("rust/src/main.rs", exit).is_empty());
        let asm = "fn f() { unsafe { core::arch::asm!(\"syscall\") } }";
        let fired = rules_fired("rust/src/engine/simd.rs", asm);
        assert_eq!(fired, vec!["R2", "R3"], "no SAFETY + asm! off-allowlist");
    }
}
