//! Lightweight runtime metrics: atomic counters + wall-clock timers.
//!
//! The paper's two evaluation axes are exactly these: **# pulls** (distance
//! computations) and **wall-clock time**. Every engine wraps its pulls in a
//! [`Counter`]; the experiment harness snapshots them per trial.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Up/down gauge for instantaneous quantities (queue depth, in-flight
/// requests). Saturates at zero on the way down rather than going negative,
/// so a spurious extra `dec` can never make a depth read as 2⁶⁴-ish.
#[derive(Debug, Default)]
pub struct Gauge(std::sync::atomic::AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(std::sync::atomic::AtomicI64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed).max(0) as u64
    }
}

/// Scope timer: `let _t = Timer::start(&cell);` adds elapsed ns on drop.
pub struct Timer<'a> {
    start: Instant,
    sink: &'a Counter,
}

impl<'a> Timer<'a> {
    pub fn start(sink: &'a Counter) -> Self {
        Timer { start: Instant::now(), sink }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.sink.add(self.start.elapsed().as_nanos() as u64);
    }
}

/// Aggregated per-run metrics snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub pulls: u64,
    pub wall: Duration,
}

impl Snapshot {
    pub fn pulls_per_arm(&self, n: usize) -> f64 {
        self.pulls as f64 / n.max(1) as f64
    }
}

/// Simple streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent_adds() {
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        c.add(1);
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_tracks_depth_and_floors_at_zero() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        assert_eq!(g.get(), 2);
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // spurious extra dec
        assert_eq!(g.get(), 0, "gauge must floor at zero");
    }

    #[test]
    fn timer_accumulates() {
        let c = Counter::new();
        {
            let _t = Timer::start(&c);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(c.get() >= 1_000_000, "timer recorded {}ns", c.get());
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of the classic dataset = 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn pulls_per_arm() {
        let s = Snapshot { pulls: 2000, wall: Duration::ZERO };
        assert_eq!(s.pulls_per_arm(1000), 2.0);
    }
}
