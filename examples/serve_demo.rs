//! Medoid-service demo: boots the TCP server on an ephemeral port,
//! registers a dataset, and walks the line-delimited JSON protocol —
//! including the PR-2 ops: `medoid_batch`, `metrics` (watch the engine
//! session cache go from miss to hit), `unregister`, and `shutdown`.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use corrsh::server;
use corrsh::util::json;

fn rpc(sock: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> json::Value {
    sock.write_all(req.as_bytes()).unwrap();
    sock.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    println!("→ {req}\n← {}", line.trim());
    json::parse(line.trim()).unwrap()
}

fn main() {
    let state = server::State::new();
    let addr = server::serve_background(state.clone()).expect("bind");
    println!("server on {addr}\n");

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());

    rpc(&mut sock, &mut reader, r#"{"op":"ping"}"#);
    let r = rpc(
        &mut sock,
        &mut reader,
        r#"{"op":"register","name":"cells","kind":"rnaseq","n":3000,"dim":512,"seed":1}"#,
    );
    assert_eq!(r.get("ok").as_bool(), Some(true));

    // Three medoid queries with different algorithms / budgets. The first
    // pays the one-time engine preparation; the rest hit the session cache.
    for req in [
        r#"{"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":16,"seed":7}"#,
        r#"{"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":64,"seed":7}"#,
        r#"{"op":"medoid","dataset":"cells","algo":"rand","refs_per_arm":500,"seed":7}"#,
    ] {
        let r = rpc(&mut sock, &mut reader, req);
        assert_eq!(r.get("ok").as_bool(), Some(true), "query failed: {r}");
    }

    // A whole seed sweep in one request, answered against the same cached
    // session.
    let r = rpc(
        &mut sock,
        &mut reader,
        r#"{"op":"medoid_batch","dataset":"cells","pulls_per_arm":24,"seeds":[0,1,2,3,4,5,6,7]}"#,
    );
    assert_eq!(r.get("jobs").as_usize(), Some(8));

    let r = rpc(&mut sock, &mut reader, r#"{"op":"stats","dataset":"cells"}"#);
    println!(
        "\ninstance hardness: H2/H̃2 gain = {:.2}",
        r.get("gain_ratio").as_f64().unwrap_or(f64::NAN)
    );

    let m = rpc(&mut sock, &mut reader, r#"{"op":"metrics"}"#);
    println!(
        "\nengine cache: {} hits / {} misses (preparation paid once); queue depth {}",
        m.get("engine_cache").get("hits").as_u64().unwrap_or(0),
        m.get("engine_cache").get("misses").as_u64().unwrap_or(0),
        m.get("executor").get("queue_depth").as_u64().unwrap_or(0),
    );

    rpc(&mut sock, &mut reader, r#"{"op":"unregister","name":"cells"}"#);
    rpc(&mut sock, &mut reader, r#"{"op":"shutdown"}"#);
    println!(
        "requests served: {}",
        state.requests.load(std::sync::atomic::Ordering::Relaxed)
    );
}
