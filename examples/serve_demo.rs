//! Medoid-service demo: boots the TCP server on an ephemeral port,
//! registers a dataset, and issues a few client queries over the
//! line-delimited JSON protocol.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use corrsh::server;
use corrsh::util::json;

fn rpc(sock: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> json::Value {
    sock.write_all(req.as_bytes()).unwrap();
    sock.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    println!("→ {req}\n← {}", line.trim());
    json::parse(line.trim()).unwrap()
}

fn main() {
    let state = server::State::new();
    let addr = server::serve_background(state.clone()).expect("bind");
    println!("server on {addr}\n");

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());

    rpc(&mut sock, &mut reader, r#"{"op":"ping"}"#);
    let r = rpc(
        &mut sock,
        &mut reader,
        r#"{"op":"register","name":"cells","kind":"rnaseq","n":3000,"dim":512,"seed":1}"#,
    );
    assert_eq!(r.get("ok").as_bool(), Some(true));

    // three medoid queries with different algorithms / budgets
    for req in [
        r#"{"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":16,"seed":7}"#,
        r#"{"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":64,"seed":7}"#,
        r#"{"op":"medoid","dataset":"cells","algo":"rand","refs_per_arm":500,"seed":7}"#,
    ] {
        let r = rpc(&mut sock, &mut reader, req);
        assert_eq!(r.get("ok").as_bool(), Some(true), "query failed: {r}");
    }

    let r = rpc(&mut sock, &mut reader, r#"{"op":"stats","dataset":"cells"}"#);
    println!(
        "\ninstance hardness: H2/H̃2 gain = {:.2}",
        r.get("gain_ratio").as_f64().unwrap_or(f64::NAN)
    );
    println!(
        "requests served: {}",
        state.requests.load(std::sync::atomic::Ordering::Relaxed)
    );
}
