//! Representative-user discovery on a synthetic Netflix-like ratings matrix
//! (cosine distance over 0.2%-dense CSR rows) — the paper's second
//! evaluation domain.
//!
//! Finds the medoid user (the most "mainstream taste" profile), then the
//! medoid of each taste archetype's neighbourhood, and prints how many
//! ratings overlap — the kind of query a recommender cold-start pipeline
//! would run.
//!
//! ```bash
//! cargo run --release --example netflix_recommend
//! ```

use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm, RandBaseline};
use corrsh::data::synth::{netflix, SynthConfig};
use corrsh::data::Data;
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine, PullEngine};
use corrsh::util::rng::Rng;

fn main() {
    let n = 20_000;
    let data = Arc::new(netflix::generate(&SynthConfig {
        n,
        dim: 4_096,
        seed: 2024,
        density: 0.002,
        clusters: 5,
        ..Default::default()
    }));
    if let Data::Sparse(s) = data.as_ref() {
        println!(
            "ratings matrix: {} users x {} movies, {:.3}% dense ({} ratings)",
            s.n,
            s.dim,
            s.density() * 100.0,
            s.nnz()
        );
    }
    let engine = CountingEngine::new(NativeEngine::with_threads(
        data.clone(),
        Metric::Cosine,
        corrsh::util::threads::default_threads(),
    ));

    // corrSH at the paper's Netflix operating point (~15-19 pulls/arm)
    let mut rng = Rng::seeded(5);
    let res = CorrSh::with_pulls_per_arm(18.0).run(&engine, &mut rng);
    println!(
        "corrSH: representative user #{} ({} pulls, {:.1}/arm, {:.2}s)",
        res.best,
        res.pulls,
        res.pulls as f64 / n as f64,
        res.wall.as_secs_f64()
    );

    // sanity: RAND with 50x the budget should agree
    engine.reset();
    let rand = RandBaseline::new(1_000).run(&engine, &mut Rng::seeded(6));
    println!(
        "RAND(m=1000): representative user #{} ({} pulls, {:.2}s)",
        rand.best,
        rand.pulls,
        rand.wall.as_secs_f64()
    );

    // profile overlap between the two candidates
    if let Data::Sparse(s) = data.as_ref() {
        let a = s.row(res.best);
        let b = s.row(rand.best);
        let (mut i, mut j, mut common) = (0, 0, 0);
        while i < a.indices.len() && j < b.indices.len() {
            match a.indices[i].cmp(&b.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    common += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        println!(
            "candidates rated {} and {} movies, {} in common; cosine distance {:.4}",
            a.nnz(),
            b.nnz(),
            common,
            engine.pull(res.best, rand.best)
        );
    }
}
