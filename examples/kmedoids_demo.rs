//! k-medoids (BUILD/SWAP/polish) on a planted Gaussian mixture — the
//! clustering workload served by `corrsh::kmedoids`, end to end.
//!
//! Generates k = 5 well-separated clusters whose exact medoids are planted
//! at points 0..5, clusters with the bandit BUILD/SWAP loop, and reports
//! how many planted centers were recovered and at what fraction of the
//! exact-algorithm pull count (exact BUILD alone sweeps k·n² distances).
//!
//! ```bash
//! cargo run --release --example kmedoids_demo
//! ```

use std::sync::Arc;

use corrsh::config::KMedoidsConfig;
use corrsh::data::synth::{gaussian, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine};
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm};
use corrsh::util::rng::Rng;

fn main() {
    let (n, k) = (2_000, 5);
    let data = Arc::new(gaussian::generate_mixture(&SynthConfig {
        n,
        dim: 16,
        seed: 42,
        clusters: k,
        ..Default::default()
    }));
    let engine = CountingEngine::new(NativeEngine::with_threads(
        data,
        Metric::L2,
        corrsh::util::threads::default_threads(),
    ));

    let cfg = KMedoidsConfig { k, ..Default::default() };
    let res = BanditKMedoids::new(cfg).run(&engine, &mut Rng::seeded(7));

    let mut medoids = res.medoids.clone();
    medoids.sort_unstable();
    let recovered = res.medoids.iter().filter(|&&m| m < k).count();
    let exact_cost = (k as u64) * (n as u64) * (n as u64);
    println!("medoids:        {medoids:?} (planted: 0..{k})");
    println!("recovered:      {recovered}/{k} planted cluster centers");
    println!("cluster sizes:  {:?}", res.cluster_sizes());
    println!("mean loss:      {:.4}", res.loss);
    println!(
        "loss trajectory: {:?}",
        res.loss_trajectory.iter().map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!(
        "pulls:          {} = build {} + swap {} + polish {}  ({:.2}% of exact {})",
        res.pulls(),
        res.build_pulls,
        res.swap_pulls,
        res.polish_pulls,
        100.0 * res.pulls() as f64 / exact_cost as f64,
        exact_cost
    );
    println!(
        "swaps:          {} accepted over {} rounds, wall {:.3}s",
        res.swaps_accepted,
        res.swap_rounds,
        res.wall.as_secs_f64()
    );
    assert_eq!(res.pulls(), engine.pulls(), "pull accounting vs engine counter");
}
