//! k-medoids clustering of synthetic single-cell RNA-Seq data, using
//! Correlated Sequential Halving as the medoid-update subroutine — the
//! motivating workload of the paper's introduction ("clustering the data to
//! discover sub-classes of cells, where medoid finding is used as a
//! subroutine").
//!
//! A PAM-style alternation: assign cells to the nearest of k medoids, then
//! recompute each cluster's medoid with corrSH (restricted to the cluster's
//! rows via an index-remapped engine view).
//!
//! ```bash
//! cargo run --release --example rnaseq_clustering
//! ```

use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::data::synth::{rnaseq, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{NativeEngine, PullEngine};
use corrsh::util::rng::Rng;

/// Engine view restricted to a subset of rows (cluster members).
struct SubsetEngine<'a> {
    inner: &'a NativeEngine,
    rows: &'a [usize],
}

impl PullEngine for SubsetEngine<'_> {
    fn n(&self) -> usize {
        self.rows.len()
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn metric(&self) -> Metric {
        self.inner.metric()
    }
    fn pull(&self, a: usize, r: usize) -> f32 {
        self.inner.pull(self.rows[a], self.rows[r])
    }
    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        let arms: Vec<usize> = arms.iter().map(|&a| self.rows[a]).collect();
        let refs: Vec<usize> = refs.iter().map(|&r| self.rows[r]).collect();
        self.inner.pull_block(&arms, &refs, out);
    }
}

fn main() {
    let k = 6;
    let n = 6_000;
    let data = Arc::new(rnaseq::generate(&SynthConfig {
        n,
        dim: 1_024,
        seed: 7,
        clusters: k,
        ..Default::default()
    }));
    let engine = NativeEngine::with_threads(
        data.clone(),
        Metric::L1,
        corrsh::util::threads::default_threads(),
    );
    let mut rng = Rng::seeded(99);

    // init: random distinct medoids
    let mut medoids = rng.sample_without_replacement(n, k);
    let mut assignment = vec![0usize; n];
    let mut total_pulls = 0u64;

    for iter in 0..8 {
        // --- assignment step: nearest medoid (k*n pulls) ------------------
        let mut dist_to = vec![0f32; n];
        let all: Vec<usize> = (0..n).collect();
        let mut best = vec![f32::MAX; n];
        for (c, &m) in medoids.iter().enumerate() {
            engine.pull_matrix(&[m], &all, &mut dist_to);
            total_pulls = total_pulls.saturating_add(n as u64);
            for i in 0..n {
                if dist_to[i] < best[i] {
                    best[i] = dist_to[i];
                    assignment[i] = c;
                }
            }
        }

        // --- update step: corrSH per cluster -------------------------------
        let mut moved = 0;
        for c in 0..k {
            let members: Vec<usize> = (0..n).filter(|&i| assignment[i] == c).collect();
            if members.len() < 2 {
                continue;
            }
            let sub = SubsetEngine { inner: &engine, rows: &members };
            let res = CorrSh::with_pulls_per_arm(24.0).run(&sub, &mut rng);
            total_pulls = total_pulls.saturating_add(res.pulls);
            let new_medoid = members[res.best];
            if new_medoid != medoids[c] {
                moved += 1;
                medoids[c] = new_medoid;
            }
        }

        let cost: f64 = best.iter().map(|&d| d as f64).sum::<f64>() / n as f64;
        println!(
            "iter {iter}: mean within-cluster distance {cost:.4}, medoids moved {moved}, \
             cumulative pulls {total_pulls} ({:.1}/point)",
            total_pulls as f64 / n as f64
        );
        if moved == 0 && iter > 0 {
            println!("converged ✓");
            break;
        }
    }

    // report cluster sizes
    let mut sizes = vec![0usize; k];
    for &a in &assignment {
        sizes[a] += 1;
    }
    println!("cluster sizes: {sizes:?}");
    let naive = (n as u64) * (n as u64) * 8 / 100; // 8 PAM iterations of exact medoid per ~1 cluster
    println!(
        "(for scale: one exact medoid pass per cluster per iteration would cost ≳{naive} pulls)"
    );
}
