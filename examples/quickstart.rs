//! Quickstart: find the medoid of a synthetic single-cell RNA-Seq dataset
//! with Correlated Sequential Halving, and compare against exact
//! computation.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use corrsh::bandits::{CorrSh, Exact, MedoidAlgorithm};
use corrsh::data::synth::{rnaseq, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine};
use corrsh::util::rng::Rng;

fn main() {
    // 1. A dataset: 4,000 synthetic cells over 1,024 genes (ℓ₁ metric, rows
    //    are probability vectors — see DESIGN.md §7 for the geometry).
    let data = rnaseq::generate(&SynthConfig {
        n: 4_000,
        dim: 1_024,
        seed: 42,
        ..Default::default()
    });

    // 2. An engine: vectorized CPU pulls with built-in pull accounting.
    let engine = CountingEngine::new(NativeEngine::new(data, Metric::L1));

    // 3. Ground truth the slow way: all n² distances.
    let exact = Exact::new().run(&engine, &mut Rng::seeded(0));
    println!(
        "exact:  medoid={} after {} pulls ({} per arm) in {:.2}s",
        exact.best,
        exact.pulls,
        exact.pulls / 4_000,
        exact.wall.as_secs_f64()
    );

    // 4. The paper's algorithm at 16 pulls/arm — ~250x fewer pulls.
    engine.reset();
    let fast = CorrSh::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(1));
    println!(
        "corrSH: medoid={} after {} pulls ({:.1} per arm) in {:.3}s [{} halving rounds]",
        fast.best,
        fast.pulls,
        fast.pulls as f64 / 4_000.0,
        fast.wall.as_secs_f64(),
        fast.rounds.len()
    );

    assert_eq!(fast.best, exact.best, "corrSH disagreed with exact on an easy instance");
    println!(
        "\nagreement ✓ — {}x fewer distance computations",
        exact.pulls / fast.pulls.max(1)
    );
}
