//! End-to-end reproduction driver — the workload that proves all layers
//! compose (DESIGN.md §3, EXPERIMENTS.md records a reference run).
//!
//! Pipeline, per dataset row:
//!   1. generate the synthetic dataset (data substrate, L3),
//!   2. resolve ground truth (exact engine sweep),
//!   3. run corrSH / Med-dit / RAND / exact over many seeded trials
//!      (bandit layer over the native engine),
//!   4. verify the PJRT path: the same corrSH trial over the AOT
//!      Pallas/JAX artifacts must return the identical medoid with the
//!      identical pull count (L1+L2+runtime+coordinator compose),
//!   5. print the paper-shaped summary (error prob, pulls/arm, wall).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_repro
//! ```

use corrsh::experiments::table1;

#[cfg(feature = "pjrt")]
fn pjrt_parity(scale: usize) -> corrsh::Result<()> {
    use std::sync::Arc;

    use corrsh::bandits::{CorrSh, MedoidAlgorithm};
    use corrsh::config::RunConfig;
    use corrsh::data::synth::Kind;
    use corrsh::distance::Metric;
    use corrsh::engine::{NativeEngine, PjrtEngine};
    use corrsh::experiments::runner;
    use corrsh::runtime::Runtime;
    use corrsh::util::rng::Rng;

    match Runtime::open("artifacts") {
        Err(e) => {
            println!("  SKIPPED: {e:#} — run `make artifacts` first");
        }
        Ok(rt) => {
            let rt = Arc::new(rt);
            let cfg = RunConfig::preset("mnist")?.scaled_down(scale);
            assert_eq!(cfg.dataset_kind, Kind::Mnist);
            let data = runner::build_data(&cfg);
            let pjrt = PjrtEngine::new(data.clone(), Metric::L2, rt.clone())?;
            pjrt.warmup()?;
            let native = NativeEngine::with_threads(data.clone(), Metric::L2, 1);

            let algo = CorrSh::with_pulls_per_arm(48.0);
            let t0 = std::time::Instant::now();
            let res_pjrt = algo.run(&pjrt, &mut Rng::seeded(123));
            let t_pjrt = t0.elapsed();
            let t0 = std::time::Instant::now();
            let res_native = algo.run(&native, &mut Rng::seeded(123));
            let t_native = t0.elapsed();

            println!(
                "  platform={} compiled_buckets={} compile_time={:.2}s",
                pjrt.runtime().platform(),
                pjrt.runtime().cached_count(),
                pjrt.runtime().compile_ns.get() as f64 / 1e9,
            );
            println!(
                "  native: medoid={} pulls={} wall={:.3}s",
                res_native.best,
                res_native.pulls,
                t_native.as_secs_f64()
            );
            println!(
                "  pjrt:   medoid={} pulls={} wall={:.3}s",
                res_pjrt.best,
                res_pjrt.pulls,
                t_pjrt.as_secs_f64()
            );
            corrsh::ensure!(
                res_pjrt.best == res_native.best && res_pjrt.pulls == res_native.pulls,
                "PJRT and native paths diverged!"
            );
            println!("  parity ✓ — all three layers compose");
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_parity(_scale: usize) -> corrsh::Result<()> {
    println!("  SKIPPED: built without the `pjrt` feature (cargo ... --features pjrt)");
    Ok(())
}

fn main() -> corrsh::Result<()> {
    let scale: usize = std::env::var("E2E_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let trials: usize = std::env::var("E2E_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(25);
    println!("e2e reproduction driver (scale 1/{scale}, {trials} trials/point)\n");

    // ---- steps 1-3 + 5: the Table-1 matrix over the native engine ---------
    let rows = table1::run(scale, trials, 0)?;

    // ---- step 4: PJRT parity on a dense row --------------------------------
    println!("\n[PJRT parity] corrSH over the AOT Pallas/JAX artifacts (mnist row, d=784)");
    pjrt_parity(scale)?;

    // ---- headline check: the paper's ordering holds -------------------------
    println!("\n[headline] per-row pull reduction vs exact computation:");
    for r in &rows {
        let corr = r.cells.iter().find(|c| c.algo.starts_with("corrSH"));
        if let Some(c) = corr {
            let exact_pulls = r.n as f64; // exact = n pulls/arm
            println!(
                "  {:<12} corrSH {:>7.1} pulls/arm vs exact {:>9.0} → {:>7.0}x reduction (err {:.1}%)",
                r.dataset,
                c.pulls_per_arm,
                exact_pulls,
                exact_pulls / c.pulls_per_arm.max(1e-9),
                c.error_pct
            );
        }
    }
    println!("\ne2e driver complete ✓ (see results/*.csv and EXPERIMENTS.md)");
    Ok(())
}
