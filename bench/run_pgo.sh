#!/usr/bin/env bash
# Profile-guided-optimization pipeline for the pull-engine hot loops
# (EXPERIMENTS.md §Perf #8, bench/perf.md):
#
#   1. baseline   `cargo bench --bench engine` → save BENCH_engine.json
#   2. instrument rebuild with -Cprofile-generate, run the engine + e2e
#                 benches as the profile workload (the corrSH round shape
#                 is the distribution that matters — not a synthetic loop)
#   3. merge      llvm-profdata merge → corrsh.profdata
#   4. rebuild    -Cprofile-use, re-run the engine bench with
#                 CORRSH_PGO=1 and CORRSH_PGO_BASELINE pointing at the
#                 saved baseline so BENCH_engine.json gains the pgo/*
#                 before/after rows CI greps.
#
# Usage: bench/run_pgo.sh [--check] [--bench-secs N]
#   --check       validate the toolchain + print the plan, run nothing
#                 (CI smoke: proves the pipeline stays runnable without
#                 paying for a full double rebuild on every push)
#   --bench-secs  per-benchmark wall budget (CORRSH_BENCH_SECS, default 3)
set -euo pipefail

cd "$(dirname "$0")/.."

CHECK=0
BENCH_SECS="${CORRSH_BENCH_SECS:-3}"
while [ $# -gt 0 ]; do
    case "$1" in
        --check) CHECK=1 ;;
        --bench-secs) BENCH_SECS="$2"; shift ;;
        *) echo "usage: bench/run_pgo.sh [--check] [--bench-secs N]" >&2; exit 2 ;;
    esac
    shift
done

HOST="$(rustc -vV | sed -n 's/^host: //p')"
LLVM_PROFDATA="$(rustc --print sysroot)/lib/rustlib/${HOST}/bin/llvm-profdata"
if [ ! -x "$LLVM_PROFDATA" ]; then
    # rustup layouts vary; fall back to whatever is on PATH.
    if command -v llvm-profdata >/dev/null 2>&1; then
        LLVM_PROFDATA="$(command -v llvm-profdata)"
    else
        echo "error: llvm-profdata not found (try: rustup component add llvm-tools)" >&2
        exit 1
    fi
fi

PGO_DIR="target/pgo"
PROFRAW_DIR="${PGO_DIR}/profraw"
PROFDATA="${PGO_DIR}/corrsh.profdata"
BASELINE="${PGO_DIR}/baseline.json"

echo "host:           ${HOST}"
echo "llvm-profdata:  ${LLVM_PROFDATA}"
echo "profile dir:    ${PROFRAW_DIR}"
echo "bench budget:   ${BENCH_SECS}s per benchmark"
if [ "$CHECK" = 1 ]; then
    echo "--check: toolchain OK, skipping the instrument/rebuild cycle"
    exit 0
fi

rm -rf "$PROFRAW_DIR"
mkdir -p "$PROFRAW_DIR"

echo "== [1/4] baseline bench (no PGO) =="
CORRSH_BENCH_SECS="$BENCH_SECS" cargo bench --bench engine
cp BENCH_engine.json "$BASELINE"

echo "== [2/4] instrumented build + profile workload =="
# Separate target dir: -C flags change the crate hash, and sharing
# ./target would thrash the non-PGO incremental cache.
RUSTFLAGS="-Cprofile-generate=$(pwd)/${PROFRAW_DIR}" \
    CARGO_TARGET_DIR="${PGO_DIR}/target-gen" \
    CORRSH_BENCH_SECS="$BENCH_SECS" \
    cargo bench --bench engine --bench e2e

echo "== [3/4] merge profiles =="
"$LLVM_PROFDATA" merge -o "$PROFDATA" "$PROFRAW_DIR"

echo "== [4/4] PGO rebuild + before/after bench =="
RUSTFLAGS="-Cprofile-use=$(pwd)/${PROFDATA}" \
    CARGO_TARGET_DIR="${PGO_DIR}/target-use" \
    CORRSH_BENCH_SECS="$BENCH_SECS" \
    CORRSH_PGO=1 \
    CORRSH_PGO_BASELINE="$BASELINE" \
    cargo bench --bench engine

echo "== pgo rows =="
grep -o '"name":"pgo/[^"]*","iters":[0-9]*,"mean_s":[0-9.e-]*' BENCH_engine.json \
    || { echo "error: BENCH_engine.json has no pgo/* rows" >&2; exit 1; }
echo "done: BENCH_engine.json now carries pgo/active + pgo/speedup_block_* (baseline kept at ${BASELINE})"
